"""Fluid-engine throughput on a 10k-job production trace.

The tentpole acceptance check of the backend-swappable fluid engine
(DESIGN.md section 16): sample active-set snapshots of a
:func:`~repro.core.trace.generate_production_trace` trace (diurnal
arrivals, heavy-tailed sizes), express each snapshot as one (flows x
links) fill problem, then rate-solve the whole corpus two ways:

  * ``python`` — the golden oracle: :func:`repro.core.fluid.fill_python`
    sequentially, one per-flow progressive-filling loop per snapshot (what
    ``FluidEngine(backend='python')`` does inside the simulator).
  * ``jnp`` / ``kernel`` — :func:`repro.core.fluid.fill_corpus`:
    size-bucketed (B, F, L) blocks, each solved in one batched
    fixed-point dispatch.

The snapshots land on a congested dumbbell fabric — two racks of four
hosts with heterogeneous NIC tiers (1/2.5/10/40 Gbps) joined by a 10 Gbps
trunk, tasks placed with a load-aware skew and ~10% of jobs spanning both
racks.  At peak-hour active sets (~800 flows) every link is oversubscribed
and the distinct per-link fair-share levels saturate one at a time, so the
progressive fill runs its full multi-round course instead of collapsing in
a round or two — the regime the per-flow python loop is worst at and the
whole reason the vectorized backends exist.

Rows land in ``BENCH_trace_throughput.json`` (run.py ``--trace-out``);
the vectorized rows' ``speedup_vs_python`` is the >=50x acceptance
metric, and ``max_abs_err_vs_python`` pins the backends to the oracle.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.metronome_testbed import MODEL_FLEET
from repro.core import fluid
from repro.core.trace import (TraceJobSpec, active_jobs_at,
                              generate_production_trace)

from . import common
from .common import emit, record_trace_row

# dumbbell fabric: 2 racks x 4 hosts with tiered NICs, one shared trunk
NIC_TIERS = (1.0, 2.5, 10.0, 40.0)
# task placement skew, load-aware-ish: big NICs soak up most tasks, so the
# per-link fair-share levels (cap / flow count) stay distinct and the
# links saturate in staggered rounds
PLACE_WEIGHTS = (0.08, 0.12, 0.30, 0.50)
TRUNK_GBPS = 10.0
CROSS_RACK_MOD = 10  # every 10th job spans both racks (crosses the trunk)

Problem = Tuple[List[float], List[Tuple[str, ...]], Dict[str, float]]


def _pick_host(h: int) -> int:
    """Deterministic weighted host tier for hash ``h`` (Knuth multiplicative
    hash -> [0, 1) -> PLACE_WEIGHTS bucket)."""
    x = (h * 2654435761 % 2**32) / 2**32
    acc = 0.0
    for k, w in enumerate(PLACE_WEIGHTS):
        acc += w
        if x < acc:
            return k
    return len(PLACE_WEIGHTS) - 1


def snapshot_problem(trace: Sequence[TraceJobSpec], t_s: float) -> Problem:
    """The fill problem of the trace's active set at ``t_s``.

    Placement is deterministic (no scheduler in the loop — this benchmarks
    the rate solve, not placement): each task lands on a weighted-hash host
    of its job's rack; cross-rack jobs alternate racks per task and their
    flows traverse the trunk."""
    demands: List[float] = []
    paths: List[Tuple[str, ...]] = []
    for ji in active_jobs_at(trace, t_s):
        spec = trace[ji]
        bw = float(MODEL_FLEET[spec.model]["bw_gbps"])
        cross = (ji % CROSS_RACK_MOD == 0)
        for k in range(spec.n_tasks):
            rack = (ji + (k % 2 if cross else 0)) % 2
            host = f"h{rack}{_pick_host(ji * 31 + k)}"
            paths.append((host, "trunk") if cross else (host,))
            demands.append(bw)
    caps = {f"h{r}{k}": NIC_TIERS[k] for r in range(2)
            for k in range(len(NIC_TIERS))}
    caps["trunk"] = TRUNK_GBPS
    return demands, paths, caps


def run() -> None:
    n_jobs = common.pick(10_000, 300)
    n_snapshots = common.pick(1024, 16)
    trace = generate_production_trace(MODEL_FLEET, n_jobs=n_jobs, seed=7)
    horizon = max(s.submit_time_s for s in trace)
    times = [horizon * (i + 0.5) / n_snapshots for i in range(n_snapshots)]
    probs = [snapshot_problem(trace, t) for t in times]
    probs = [p for p in probs if p[0]]  # drop empty off-peak snapshots
    mats = [fluid.problem_matrix(d, p, c)[:3] for d, p, c in probs]
    n_flows = sum(len(p[0]) for p in probs)

    # oracle: sequential per-snapshot python fills; best of 2 passes so a
    # background hiccup doesn't flatter the vectorized speedups
    py_s = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        golden = [fluid.fill_python(np.asarray(d, dtype=float), p, c)
                  for d, p, c in probs]
        py_s = min(py_s, time.perf_counter() - t0)
    record_trace_row(name="trace_fill_python", backend="python",
                     n_jobs=n_jobs, n_problems=len(probs), n_flows=n_flows,
                     seconds=py_s, problems_per_s=len(probs) / py_s,
                     flows_per_s=n_flows / py_s, speedup_vs_python=1.0,
                     max_abs_err_vs_python=0.0)
    emit("trace_fill_python", py_s * 1e6 / len(probs),
         f"n_jobs={n_jobs};n_problems={len(probs)};n_flows={n_flows}")

    for backend in ("jnp", "kernel"):
        rates = fluid.fill_corpus(mats, backend=backend)  # warmup (jit)
        best = float("inf")
        for _ in range(common.pick(5, 1)):
            t0 = time.perf_counter()
            rates = fluid.fill_corpus(mats, backend=backend)
            best = min(best, time.perf_counter() - t0)
        err = max(float(np.max(np.abs(r - g))) if len(g) else 0.0
                  for r, g in zip(rates, golden))
        record_trace_row(name=f"trace_fill_{backend}", backend=backend,
                         n_jobs=n_jobs, n_problems=len(probs),
                         n_flows=n_flows, seconds=best,
                         problems_per_s=len(probs) / best,
                         flows_per_s=n_flows / best,
                         speedup_vs_python=py_s / best,
                         max_abs_err_vs_python=err)
        emit(f"trace_fill_{backend}", best * 1e6 / len(probs),
             f"speedup={py_s / best:.1f}x;max_abs_err={err:.3g}")

"""Kernel micro-benchmarks (beyond paper): flash attention / score kernel /
rg-lru vs their jnp references, CPU wall-time (interpret-mode correctness is
covered by tests; these numbers track the XLA reference path)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.core import geometry as G
from repro.core import rotation as R

from . import common
from .common import Timer, emit


def _bench(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> None:
    key = jax.random.PRNGKey(0)
    # attention reference path (the dry-run fallback)
    for s in common.pick((512, 1024), (128,)):
        q = jax.random.normal(key, (1, 8, s, 64), jnp.float32)
        k = jax.random.normal(key, (1, 2, s, 64), jnp.float32)
        v = jax.random.normal(key, (1, 2, s, 64), jnp.float32)
        us = _bench(jax.jit(lambda a, b, c: ref.attention_ref(
            a, b, c, causal=True)), q, k, v)
        flops = 4 * s * s * 64 * 8
        emit(f"kernel_attention_ref_s{s}", us,
             f"gflops_per_s={flops/us/1e3:.1f}")

    # metronome scoring: exhaustive enumeration throughput (Eq. 18)
    pats = G.pattern_matrix([1, 1, 1], [0.3, 0.3, 0.3], 72)
    bw = np.array([20.0, 20.0, 20.0])
    with Timer() as t:
        res = R.find_optimal_rotation(pats, bw, 25.0, [1, 1, 1], 0)
    emit("kernel_score_enumeration_3tasks", t.us,
         f"combos={res.n_evaluated};combos_per_s={res.n_evaluated/(t.us/1e6):.0f}")

    # rg-lru associative scan reference
    rg_shape = common.pick((4, 2048, 512), (2, 256, 128))
    a = jax.nn.sigmoid(jax.random.normal(key, rg_shape)) * 0.3 + 0.65
    x = jax.random.normal(key, rg_shape, jnp.float32)
    us = _bench(jax.jit(ref.rg_lru_ref), a, x)
    emit(f"kernel_rg_lru_ref_{'x'.join(map(str, rg_shape))}", us,
         f"melems_per_s={rg_shape[0]*rg_shape[1]*rg_shape[2]/us:.1f}")

"""Graceful degradation under an imperfect-information control plane.

The acceptance bench of DESIGN.md section 19: every scheduler/controller
read of allocatable bandwidth is routed through a
:class:`~repro.core.telemetry.TelemetryChannel` (sampled, noisy, stale,
lossy observation) while the fluid physics keeps running on ground truth,
and the environment additionally misbehaves (flapping link failures,
silently drifting traffic profiles).  Four distortion axes are swept, each
against its own ``x == 0`` anchor:

  * ``noise``     — multiplicative telemetry noise std on the dynamic
    snapshots D1/D2 (background ramp / capacity drop mid-run).
  * ``staleness`` — observation pipeline delay (ms) on D2 at fixed 10%%
    noise.
  * ``failure``   — flapping-cycle count of the R1 spine-uplink
    failure/recovery train at fixed 10%% noise.
  * ``trace``     — telemetry noise on a small online Gavel-style trace
    (arrivals + queueing, the Fig. 10 regime).

Two policies run every point:

  * ``metronome``        — the oracle-assuming ablation: it believes every
    observation and replans on every reported change.
  * ``metronome-robust`` — degradation control ON: hysteresis debounce on
    reconfiguration (min-interval + magnitude threshold) and
    measured-vs-declared demand reconciliation.

Each row is seed-averaged; ``degradation`` is the job-mean
time-per-1000-iterations ratio against the same (axis, scenario, policy)
group's anchor.  The graceful-degradation claim checked in CI
(``scripts/diff_bench.py``) and pinned by the committed artifact: the
robust policy's curve must stay SHALLOWER than the ablation's on the
failure axis, where believing a flapping link costs full replans.

Rows land in ``BENCH_robustness.json`` (run.py ``--robustness-out``).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List

import numpy as np

from repro.configs.metronome_testbed import (MODEL_FLEET, dynamic_scenario,
                                             fault_scenario, trace_scenario)
from repro.core import experiment
from repro.core.experiment import Policy, Scenario
from repro.core.simulator import SimConfig
from repro.core.telemetry import TelemetryChannel
from repro.core.trace import generate_trace

from . import common
from .common import Timer, emit, record_robustness_row

SAMPLE_PERIOD_MS = 1000.0

# the oracle-assuming ablation vs degradation control ON (same scheduler,
# same thresholds — ONLY the robustness machinery differs)
POLICIES = (
    Policy("metronome"),
    Policy("metronome", label="metronome-robust").with_options(
        hysteresis_ms=3000.0, hysteresis_frac=0.05, reconcile=True),
)

NOISE_GRID = (0.0, 0.05, 0.1, 0.2, 0.4)
STALENESS_GRID = (0.0, 2_000.0, 5_000.0, 10_000.0)
FLAP_GRID = (0, 2, 4, 8)
TRACE_NOISE_GRID = (0.0, 0.2)

# fixed noise for the staleness/failure axes: distortions compose in
# deployment, so the non-swept channel knobs stay at a realistic operating
# point instead of zero
AXIS_BASE_NOISE = 0.1


def _channel(noise: float = 0.0, staleness: float = 0.0) -> TelemetryChannel:
    return TelemetryChannel(sample_period_ms=SAMPLE_PERIOD_MS,
                            noise_std=noise, staleness_ms=staleness)


def _point(scn_factory: Callable[[], Scenario], policy: Policy,
           cfg_factory: Callable[[int], SimConfig],
           seeds) -> Dict[str, float]:
    """Seed-averaged measurements of one (axis, scenario, policy, x) cell."""
    cols: Dict[str, List[float]] = {k: [] for k in (
        "t1000", "hi", "lo", "readj", "reconf", "supp", "recon")}
    for seed in seeds:
        r = experiment.run(scn_factory(), policy, cfg_factory(seed))
        cols["t1000"].append(r.mean_s_per_1000())
        cols["hi"].append(r.mean_s_per_1000(r.high_priority))
        cols["lo"].append(r.mean_s_per_1000(r.low_priority))
        cols["readj"].append(float(r.sim.readjustments))
        cols["reconf"].append(float(r.sim.reconfigurations))
        cols["supp"].append(float(r.sim.suppressed_reconfigurations))
        cols["recon"].append(float(r.sim.reconciliations))
    return {k: float(np.nanmean(v)) if any(not math.isnan(x) for x in v)
            else math.nan
            for k, v in cols.items()}


def _sweep_axis(axis: str, scenario: str, xs, seeds,
                scn_for: Callable[[float], Callable[[], Scenario]],
                cfg_for: Callable[[float], Callable[[int], SimConfig]]
                ) -> None:
    """One axis x policy sweep: measure every x, anchor degradation on the
    x == 0 point of the same policy, record + emit the rows."""
    for pol in POLICIES:
        anchor = None
        for x in xs:
            with Timer() as t:
                m = _point(scn_for(x), pol, cfg_for(x), seeds)
            if anchor is None:
                anchor = m["t1000"]  # xs always starts at 0
            deg = m["t1000"] / anchor if anchor else math.nan
            record_robustness_row(
                axis=axis, scenario=scenario, policy=pol.name, x=float(x),
                seeds=len(seeds), t1000_mean_s=m["t1000"],
                t1000_hi_s=m["hi"], t1000_lo_s=m["lo"], degradation=deg,
                readjustments=m["readj"], reconfigurations=m["reconf"],
                suppressed_reconfigurations=m["supp"],
                reconciliations=m["recon"])
            emit(f"robust_{axis}_{scenario}_x{x:g}_{pol.name}",
                 t.us / len(seeds),
                 f"t1000_s={m['t1000']:.2f};deg={deg:.3f};"
                 f"readj={m['readj']:.1f};reconf={m['reconf']:.1f};"
                 f"supp={m['supp']:.1f};recon={m['recon']:.1f}")


def run() -> None:
    seeds = common.pick((3, 4, 5), (3,))
    n_iter = common.pick(300, 25)
    dur_ms = common.pick(150_000.0, 15_000.0)

    def snap_cfg(chan: TelemetryChannel) -> Callable[[int], SimConfig]:
        return lambda seed: SimConfig(duration_ms=dur_ms, seed=seed,
                                      jitter_std=0.01, telemetry=chan)

    # -- axis 1: telemetry noise on the dynamic snapshots ----------------
    for sid in common.pick(("D1", "D2"), ("D1",)):
        _sweep_axis(
            "noise", sid, common.pick(NOISE_GRID, (0.0, 0.2)), seeds,
            scn_for=lambda x, sid=sid: (
                lambda: dynamic_scenario(
                    sid, n_iterations=n_iter,
                    t_on_ms=common.pick(15_000.0, 4_000.0),
                    t_off_ms=common.pick(45_000.0, 12_000.0))),
            cfg_for=lambda x: snap_cfg(_channel(noise=x)))

    # -- axis 2: observation staleness (D2, fixed 10% noise) -------------
    _sweep_axis(
        "staleness", "D2",
        common.pick(STALENESS_GRID, (0.0, 5_000.0)), seeds,
        scn_for=lambda x: (
            lambda: dynamic_scenario(
                "D2", n_iterations=n_iter,
                t_on_ms=common.pick(15_000.0, 4_000.0),
                t_off_ms=common.pick(45_000.0, 12_000.0))),
        cfg_for=lambda x: snap_cfg(
            _channel(noise=AXIS_BASE_NOISE, staleness=x)))

    # -- axis 3: flapping-failure cycles (R1, fixed 10% noise) -----------
    # the hysteresis showcase: every flap transition is a real
    # on_link_change, so the ablation replans 2x per cycle while the
    # robust policy sits short flaps out inside its debounce window
    _sweep_axis(
        "failure", "R1", common.pick(FLAP_GRID, (0, 2)), seeds,
        scn_for=lambda x: (
            lambda: fault_scenario(
                "R1", n_iterations=n_iter,
                start_ms=common.pick(20_000.0, 3_000.0),
                period_ms=common.pick(15_000.0, 1_500.0),
                down_ms=common.pick(2_000.0, 300.0), n_cycles=int(x))),
        cfg_for=lambda x: snap_cfg(_channel(noise=AXIS_BASE_NOISE)))

    # -- axis 4: telemetry noise on an online trace ----------------------
    trace = generate_trace(
        MODEL_FLEET, duration_s=common.pick(900.0, 240.0), total_gpus=13,
        target_load=0.85, seed=1,
        job_duration_range_s=(120.0, 240.0))[:common.pick(8, 3)]
    trace_dur = common.pick(600_000.0, 45_000.0)
    _sweep_axis(
        "trace", "gavel-small", TRACE_NOISE_GRID, seeds,
        scn_for=lambda x: (
            lambda: trace_scenario(trace, open_ended=True,
                                   name="gavel-small")),
        cfg_for=lambda x: (
            lambda seed: SimConfig(duration_ms=trace_dur, seed=seed,
                                   jitter_std=0.01,
                                   telemetry=_channel(noise=x))))

"""Fig. 16: scheduler execution time vs number of contending jobs, plus the
stop-and-wait controller's offline recalculation time (<5 s in the paper)."""
from __future__ import annotations

import time

from repro.core.cluster import Cluster, Node, Resources
from repro.core.controller import StopAndWaitController
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.baselines import DefaultPlugin, DiktyoPlugin
from repro.core.workload import Workload, make_job

from .common import Timer, emit, pick


def _cluster():
    nodes = [Node(f"n{i}", Resources(cpu=64, mem=512, gpu=8), bw_gbps=25.0)
             for i in range(4)]
    return Cluster(nodes)


def run() -> None:
    periods = [96.0, 90.0, 120.0, 245.0, 80.0]
    # --smoke still covers the contended regimes (0, 2 and 4 existing jobs)
    # so the BENCH_sched_time.json trajectory keeps its headline rows
    for n_existing in pick(range(0, 5), (0, 2, 4)):
        for plugin_name, plugin_fn in (
            ("metronome", lambda c: MetronomePlugin(controller=c)),
            ("default", lambda c: DefaultPlugin()),
            ("diktyo", lambda c: DiktyoPlugin()),
        ):
            cluster = _cluster()
            ctrl = StopAndWaitController()
            fw = SchedulingFramework(cluster, plugin_fn(ctrl))
            for i in range(n_existing):
                j = make_job(f"bg-{i}", n_tasks=2, period_ms=periods[i],
                             duty=0.45, bw_gbps=20.0)
                fw.schedule_workload(Workload(name=j.name, jobs=[j]))
            new = make_job("new", n_tasks=2, period_ms=96.0, duty=0.45,
                           bw_gbps=20.0)
            reps = pick(5, 2)
            t0 = time.perf_counter()
            for r in range(reps):
                for t in new.tasks:
                    t.node = None
                wl = Workload(name=f"new-{r}", jobs=[new])
                fw.schedule_workload(wl)
                fw.evict_job(new)
            us = (time.perf_counter() - t0) / reps * 1e6
            emit(f"fig16_sched_{plugin_name}_{n_existing}jobs", us,
                 f"ms_per_pod={us/2/1000:.2f}")
        # controller offline recalculation time at this contention level
        cluster = _cluster()
        ctrl = StopAndWaitController()
        fw = SchedulingFramework(cluster, MetronomePlugin(controller=ctrl))
        for i in range(n_existing + 1):
            j = make_job(f"bg-{i}", n_tasks=2, period_ms=periods[i],
                         duty=0.45, bw_gbps=20.0)
            fw.schedule_workload(Workload(name=j.name, jobs=[j]))
        ctrl.pending_recalc = list(ctrl.links.keys())
        with Timer() as t:
            ctrl.run_offline_recalculation(fw.registry, cluster)
        emit(f"fig16_recalc_{n_existing + 1}jobs", t.us,
             f"s={t.us/1e6:.3f}")

"""Content-keyed sweep-result cache for the nightly benchmark job.

The nightly CI run executes the NON-smoke benchmark grid, which is minutes
per bench.  Most nights nothing that feeds a given sweep has changed, so
``run.py --cache-dir .bench_cache`` lets :func:`benchmarks.common.run_sweep`
skip cells whose inputs are byte-identical to a previous night:

  * The key is a sha256 over the *materialized scenario content* — cluster
    (nodes, host/uplink capacities, latency, topology), every job's traffic
    spec, the background/event streams, the policy names, and the resolved
    ``SimConfig`` — plus ``results.SCHEMA_VERSION``.  Renaming a builder
    does not invalidate; changing any input that can alter a result does.
    (Code changes inside the simulator are covered by the CI cache key,
    which hashes ``src/**`` — see .github/workflows/ci.yml.)
  * The value is the full ``SweepResult.to_json_dict(include_durations=
    True)`` payload, so a cache hit restores bit-identical artifacts.

Corrupt or schema-drifted entries are treated as misses, never errors.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Optional, Sequence

import numpy as np

from repro.core.experiment import Policy, Scenario
from repro.core.results import SCHEMA_VERSION, SweepResult
from repro.core.simulator import SimConfig


def _canon(obj: Any) -> Any:
    """JSON-serializable canonical form of arbitrary scenario content."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {"__dc__": type(obj).__name__,
                **{f.name: _canon(getattr(obj, f.name))
                   for f in dataclasses.fields(obj)}}
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in sorted(obj.items(),
                                                     key=lambda kv: str(kv[0]))}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)  # last resort: stable for value-ish objects


def _cluster_canon(cluster) -> Any:
    topo = cluster.topology
    return {
        "nodes": [_canon(cluster.nodes[n]) for n in cluster.node_names],
        "latency": cluster.latency.tolist(),
        "leaf_of": _canon(topo.leaf_of),
        "uplinks": _canon(topo.uplinks),
    }


def fingerprint(scenario: Scenario, policies: Sequence[Policy],
                cfg: Optional[SimConfig]) -> str:
    """sha256 over the sweep cell inputs (materialized, not by name)."""
    cluster, workloads, background, events = scenario.materialize()
    doc = {
        "schema_version": SCHEMA_VERSION,
        "mode": scenario.mode,
        "cluster": _cluster_canon(cluster),
        "workloads": _canon(workloads),
        "background": _canon(background),
        "events": _canon(events),
        # full knob content, not p.name: a custom label would otherwise
        # make two different policies share a cache key
        "policies": [_canon(p) for p in policies],
        "sim_config": _canon(cfg) if cfg is not None else None,
        "scenario_sim_config": (_canon(scenario.sim_config)
                                if scenario.sim_config is not None else None),
    }
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def fingerprint_grid(scenarios: Sequence[Scenario],
                     policies: Sequence[Policy],
                     cfg: Optional[SimConfig]) -> str:
    """Key of a whole ``run_sweep`` grid: the per-scenario fingerprints
    concatenated (order matters — cells are recorded row-major)."""
    blob = "|".join(fingerprint(s, policies, cfg) for s in scenarios)
    return hashlib.sha256(blob.encode()).hexdigest()


def load(cache_dir: str, key: str) -> Optional[SweepResult]:
    """Cached SweepResult for ``key``, or None (miss / corrupt / drifted)."""
    path = os.path.join(cache_dir, f"{key}.json")
    try:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema_version") != SCHEMA_VERSION:
            return None
        return SweepResult.from_json_dict(doc)
    except (OSError, ValueError, KeyError, TypeError):
        return None


def store(cache_dir: str, key: str, sweep: SweepResult) -> None:
    os.makedirs(cache_dir, exist_ok=True)
    path = os.path.join(cache_dir, f"{key}.json")
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(sweep.to_json_dict(include_durations=True), f,
                  allow_nan=False)
    os.replace(tmp, path)

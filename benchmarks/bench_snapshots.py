"""Fig. 7/8: per-snapshot iteration time (high/low priority) per scheduler,
plus Table V: average bandwidth utilization deltas."""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import SNAPSHOTS
from repro.core.harness import priority_split

from .common import SCHEDULERS, Timer, emit, run_snapshot_all


def run() -> None:
    for sid in SNAPSHOTS:
        with Timer() as t:
            results = run_snapshot_all(sid)
        wls = results.pop("_workloads")
        hi, lo = priority_split(wls)
        me = results["metronome"]
        for sched in SCHEDULERS:
            r = results[sched]
            hi_t = np.mean([r.sim.time_per_1000_iters_s[j] for j in hi]) if hi else float("nan")
            lo_t = np.mean([r.sim.time_per_1000_iters_s[j] for j in lo]) if lo else float("nan")
            emit(f"fig7_{sid}_{sched}", t.us / len(SCHEDULERS),
                 f"hi_s_per_1000={hi_t:.2f};lo_s_per_1000={lo_t:.2f};"
                 f"gamma={r.sim.avg_bw_utilization:.4f};"
                 f"readj={r.sim.readjustments}")
        # Fig. 8-style accelerations of Metronome vs De/Di (+ vs ideal gap)
        for other in ("default", "diktyo"):
            o = results[other]
            if hi:
                acc = 100.0 * (1 - np.mean([me.sim.time_per_1000_iters_s[j]
                                            for j in hi])
                               / np.mean([o.sim.time_per_1000_iters_s[j]
                                          for j in hi]))
                emit(f"fig8_{sid}_hi_accel_vs_{other}", 0.0,
                     f"accel_pct={acc:.2f}")
            if lo:
                acc = 100.0 * (1 - np.mean([me.sim.time_per_1000_iters_s[j]
                                            for j in lo])
                               / np.mean([o.sim.time_per_1000_iters_s[j]
                                          for j in lo]))
                emit(f"fig8_{sid}_lo_accel_vs_{other}", 0.0,
                     f"accel_pct={acc:.2f}")
        if hi:
            gap = 100.0 * (np.mean([me.sim.time_per_1000_iters_s[j] for j in hi])
                           / np.mean([results["ideal"].sim.time_per_1000_iters_s[j]
                                      for j in hi]) - 1)
            emit(f"claim_{sid}_hi_vs_ideal", 0.0, f"gap_pct={gap:.2f}")
        # Table V: gamma deltas (percentage points and relative %)
        for other in ("default", "diktyo", "ideal"):
            g_me = me.sim.avg_bw_utilization
            g_o = results[other].sim.avg_bw_utilization
            rel = 100.0 * (g_me - g_o) / max(g_o, 1e-9)
            emit(f"tableV_{sid}_vs_{other}", 0.0,
             f"gamma_delta_pp={100*(g_me-g_o):.2f};gamma_rel_pct={rel:.2f}")

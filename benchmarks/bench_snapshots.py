"""Fig. 7/8: per-snapshot iteration time (high/low priority) per scheduler,
plus Table V: average bandwidth utilization deltas."""
from __future__ import annotations

from repro.configs.metronome_testbed import SNAPSHOTS

from .common import SCHEDULER_NAMES, Timer, emit, snapshot_sweep


def run() -> None:
    for sid in SNAPSHOTS:
        with Timer() as t:
            sw = snapshot_sweep(sid, origin="snapshots")
        me = sw.get(sid, "metronome")
        hi, lo = me.high_priority, me.low_priority
        for sched in SCHEDULER_NAMES:
            r = sw.get(sid, sched)
            emit(f"fig7_{sid}_{sched}", t.us / len(SCHEDULER_NAMES),
                 f"hi_s_per_1000={r.mean_s_per_1000(hi):.2f};"
                 f"lo_s_per_1000={r.mean_s_per_1000(lo):.2f};"
                 f"gamma={r.sim.avg_bw_utilization:.4f};"
                 f"readj={r.sim.readjustments}")
        # Fig. 8-style accelerations of Metronome vs De/Di (+ vs ideal gap)
        for other in ("default", "diktyo"):
            o = sw.get(sid, other)
            if hi:
                acc = 100.0 * (1 - me.mean_s_per_1000(hi)
                               / o.mean_s_per_1000(hi))
                emit(f"fig8_{sid}_hi_accel_vs_{other}", 0.0,
                     f"accel_pct={acc:.2f}")
            if lo:
                acc = 100.0 * (1 - me.mean_s_per_1000(lo)
                               / o.mean_s_per_1000(lo))
                emit(f"fig8_{sid}_lo_accel_vs_{other}", 0.0,
                     f"accel_pct={acc:.2f}")
        if hi:
            gap = 100.0 * (me.mean_s_per_1000(hi)
                           / sw.get(sid, "ideal").mean_s_per_1000(hi) - 1)
            emit(f"claim_{sid}_hi_vs_ideal", 0.0, f"gap_pct={gap:.2f}")
        # Table V: gamma deltas (percentage points and relative %)
        for other in ("default", "diktyo", "ideal"):
            g_me = me.sim.avg_bw_utilization
            g_o = sw.get(sid, other).sim.avg_bw_utilization
            rel = 100.0 * (g_me - g_o) / max(g_o, 1e-9)
            emit(f"tableV_{sid}_vs_{other}", 0.0,
                 f"gamma_delta_pp={100*(g_me-g_o):.2f};gamma_rel_pct={rel:.2f}")

"""End-to-end dynamic event-loop throughput on a 10k-job production trace.

The acceptance check of the array event loop (DESIGN.md section 17): drive
the FULL dynamic simulation — online arrivals with queueing and eviction,
:class:`JobDeparture` truncation, synthetic background/capacity/traffic
events (including unknown-target offenders, exercising the structured
warnings), stop-and-wait reconfiguration ON — over a
:func:`~repro.core.trace.generate_production_trace` trace compressed onto
an oversubscribed leaf–spine fabric, and time ``ClusterSimulator.run()``
three ways:

  * ``legacy`` / ``python`` — the pre-array per-object loop, preserved
    verbatim as ``SimConfig(event_loop='legacy')``: the pre-PR baseline.
  * ``array`` / ``python`` — the vectorized loop on the float64 oracle
    backend; asserted BIT-FOR-BIT equal to the legacy row in-process (the
    oracle-parity contract, also pinned in ``tests/test_event_loop.py``).
    Its ``speedup_vs_legacy`` is the >=10x acceptance metric.
  * ``array`` / ``jnp`` — dirty affinity components batched through one
    shape-bucketed ``fluid.fill_corpus`` per tick; sampled in-loop solves
    are re-solved with ``fill_python`` for ``max_abs_err_vs_oracle``
    (<=1e-6 acceptance), and the corpus bucket occupancy rides along so
    batch-padding waste is visible.

Rows land in ``BENCH_dynamic_throughput.json`` (run.py ``--dynamic-out``);
``scripts/diff_bench.py --min-speedup`` gates the array/python row in CI.
Per-phase ``SimConfig.profile`` timings are attached to every row and also
emitted as ``common.RECORDED_EMITS`` timing rows.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Tuple

import numpy as np

from repro.configs.metronome_testbed import MODEL_FLEET
from repro.core import events as events_mod
from repro.core import fluid
from repro.core.cluster import make_fabric_cluster
from repro.core.experiment import Policy, Scenario, build_scheduler
from repro.core.framework import SchedulingFramework
from repro.core.simulator import ClusterSimulator, SimConfig
from repro.core.topology import uplink_id
from repro.core.trace import (TraceJobSpec, generate_production_trace,
                              trace_departure_events, trace_job_name,
                              trace_to_jobs)
from repro.core.workload import Workload

from . import common
from .common import emit, record_dynamic_row

# Small leaf-spine fabric + short heavy-tailed job durations: Metronome
# admission costs O(pods x nodes x active jobs) and is SHARED by both
# loops, so the fabric and the trace's active concurrency are sized down
# until shared scheduling is a rounding error and wall clock is dominated
# by the event loop itself — the thing this bench compares.  The trace's
# diurnal peak still oversubscribes the 16 chips (queueing + eviction
# retries run), and 2-leaf placements push flows over the 2:1 uplinks
# (multi-link progressive fills + single<->multi mode flips).
N_LEAVES = 2
HOSTS_PER_LEAF = 2
OVERSUBSCRIPTION = 2.0

# short-duration production trace: ~3-4 concurrently active jobs on
# average (vs 16-chip capacity) out of 10k total — the legacy loop's
# per-tick cost scales with TOTAL jobs admitted so far (DONE included),
# the array loop's with the active set; this gap is the tentpole
TRACE_KW = dict(median_duration_s=20.0, duration_sigma=1.0,
                duration_clip_s=(8.0, 80.0), task_multipliers=(1, 2),
                task_weights=(0.85, 0.15))

# trace compression: the 24 h submission window plays out in
# ~horizon * TIME_SCALE simulated seconds; iteration counts (and thus
# event-loop ticks) scale with it — 0.06 gives the median job ~6-16
# comm/compute iterations before its departure event truncates it.
# Tick count is also the Amdahl lever against the SHARED per-admission
# scheduling cost both loops pay identically (~1.25 ms/tick amortized at
# 0.03, which capped end-to-end speedup at ~7.7x even with the array
# loop's core 120x faster per tick); 0.06 doubles the ticks over the
# same 10k admissions so the loops themselves dominate wall clock.
TIME_SCALE = 0.06

# synthetic dynamic-environment events: periodic background ramps and
# capacity dips on a few links, traffic changes on real jobs, plus
# unknown-target offenders (one bad link, one bad job name — the
# structured-warning path runs in the timed loop, once per offender)
N_EVENT_BURSTS = 24


def synthetic_events(trace: Tuple[TraceJobSpec, ...], horizon_ms: float,
                     time_scale: float) -> List[events_mod.Event]:
    """Deterministic bg/capacity/traffic bursts across the run."""
    evs: List[events_mod.Event] = []
    hosts = [f"leaf{k}-host0" for k in range(min(4, N_LEAVES))]
    uplink = uplink_id("leaf0")
    for b in range(N_EVENT_BURSTS):
        t0 = horizon_ms * (b + 0.25) / N_EVENT_BURSTS
        t1 = horizon_ms * (b + 0.75) / N_EVENT_BURSTS
        host = hosts[b % len(hosts)]
        evs.append(events_mod.BackgroundFlowChange(t0, link=host,
                                                   rate_gbps=8.0))
        evs.append(events_mod.BackgroundFlowChange(t1, link=host,
                                                   rate_gbps=0.0))
        if b % 3 == 0:
            evs.append(events_mod.LinkCapacityChange(
                t0, link=uplink, allocatable_gbps=0.6 * HOSTS_PER_LEAF
                * 25.0 / OVERSUBSCRIPTION))
            evs.append(events_mod.LinkCapacityChange(
                t1, link=uplink, allocatable_gbps=None,
                capacity_gbps=HOSTS_PER_LEAF * 25.0 / OVERSUBSCRIPTION))
        if b % 4 == 0 and trace:
            ji = (b * 37) % len(trace)
            evs.append(events_mod.TrafficChange(
                t0, job=trace_job_name(trace[ji], ji),
                duty_mult=1.25 if b % 8 else 0.8))
    # unknown-target offenders: ignored (with ONE structured warning each)
    evs.append(events_mod.BackgroundFlowChange(horizon_ms * 0.1,
                                               link="ghost-host",
                                               rate_gbps=5.0))
    evs.append(events_mod.BackgroundFlowChange(horizon_ms * 0.2,
                                               link="ghost-host",
                                               rate_gbps=9.0))
    evs.append(events_mod.TrafficChange(horizon_ms * 0.15, job="ghost-job",
                                        duty_mult=2.0))
    return evs


@dataclasses.dataclass(frozen=True)
class DynamicTraceBuild:
    """Picklable build: production trace + departures + synthetic events on
    the oversubscribed bench fabric."""

    trace: Tuple[TraceJobSpec, ...]
    time_scale: float = TIME_SCALE

    def __call__(self):
        cluster = make_fabric_cluster(
            n_leaves=N_LEAVES, hosts_per_leaf=HOSTS_PER_LEAF,
            bw_gbps=25.0, oversubscription=OVERSUBSCRIPTION)
        jobs = trace_to_jobs(list(self.trace), MODEL_FLEET,
                             time_scale=self.time_scale, open_ended=True)
        wls = []
        for j in jobs:
            wl = Workload(name=j.name, jobs=[j])
            j.workload = wl.name
            for t in j.tasks:
                t.workload = wl.name
            wls.append(wl)
        horizon_ms = max(
            (s.submit_time_s + s.duration_s) for s in self.trace
        ) * self.time_scale * 1e3
        events = list(trace_departure_events(list(self.trace),
                                             time_scale=self.time_scale))
        events.extend(synthetic_events(self.trace, horizon_ms,
                                       self.time_scale))
        return cluster, wls, (), events


def _horizon_ms(trace, time_scale: float) -> float:
    return max((s.submit_time_s + s.duration_s) for s in trace) \
        * time_scale * 1e3


def run_trace_sim(scen: Scenario, policy: Policy, cfg: SimConfig):
    """The experiment.run TRACE branch, opened up so the bench can reach
    the live simulator (fluid-engine sampling, corpus stats) and time
    ``run()`` alone — identical construction for every row."""
    if (policy.sim_backend is not None
            and cfg.fluid_backend != policy.sim_backend):
        cfg = dataclasses.replace(cfg, fluid_backend=policy.sim_backend)
    cluster, workloads, background, events = scen.materialize()
    plugin, controller = build_scheduler(policy)
    fw = SchedulingFramework(cluster.copy(), plugin)
    sim = ClusterSimulator(
        fw.cluster, [], cfg, controller=controller, background=background,
        registry=fw.registry, framework=fw, arrivals=workloads,
        events=events, offline_recalc=not policy.skip_third_stage,
    )
    return sim, len(events)


def _assert_parity(a, b) -> None:
    """Array/python must replay legacy/python bit-for-bit."""
    assert a.durations_ms == b.durations_ms, "durations diverged"
    assert a.iterations_done == b.iterations_done, "iterations diverged"
    assert a.link_utilization == b.link_utilization, "utilization diverged"
    for k in a.finish_times_ms:
        x, y = a.finish_times_ms[k], b.finish_times_ms[k]
        assert (math.isnan(x) and math.isnan(y)) or x == y, \
            f"finish time diverged for {k}"
    assert a.avg_bw_utilization == b.avg_bw_utilization, "gamma diverged"
    assert a.readjustments == b.readjustments
    assert a.reconfigurations == b.reconfigurations


def _emit_profile(name: str, prof) -> None:
    ticks = max(1, prof.ticks)
    for phase, secs in prof.phase_seconds().items():
        emit(f"{name}_{phase}", secs * 1e6 / ticks,
             f"ticks={prof.ticks};solves={prof.solves};"
             f"skipped={prof.skipped_assigns};"
             f"events={prof.events_applied}")


def run() -> None:
    n_jobs = common.pick(10_000, 250)
    trace = tuple(generate_production_trace(MODEL_FLEET, n_jobs=n_jobs,
                                            seed=7, **TRACE_KW))
    time_scale = TIME_SCALE
    duration_ms = _horizon_ms(trace, time_scale) + 1_000.0
    scen = Scenario.trace(name="dynamic-trace",
                          build=DynamicTraceBuild(trace, time_scale))
    # skip_third_stage: per-admission offline recalculation is shared
    # (identical) work for every row — off, so the loop dominates.
    # rotation_joint=False: the joint offset planner is EXPONENTIAL in
    # affinity-component size (a single 7-job overlap costs minutes of
    # exhaustive combo search); the legacy uplink-wins reconciliation keeps
    # admission O(link) while stop-and-wait reconfiguration stays ON.
    policy = Policy("metronome", skip_third_stage=True,
                    rotation_joint=False)
    base_cfg = SimConfig(duration_ms=duration_ms, seed=3, jitter_std=0.01,
                         profile=True)

    results = {}
    for loop, backend in (("legacy", "python"), ("array", "python"),
                          ("array", "jnp")):
        cfg = dataclasses.replace(base_cfg, event_loop=loop)
        row_policy = (policy if backend == "python"
                      else dataclasses.replace(policy, sim_backend=backend))
        sim, n_events = run_trace_sim(scen, row_policy, cfg)
        if backend != "python":
            sim.fluid.sample_stride = 7  # audit in-loop solves vs oracle
        t0 = time.perf_counter()
        res = sim.run()
        seconds = time.perf_counter() - t0
        results[(loop, backend)] = (sim, res, seconds, n_events)

    legacy_s = results[("legacy", "python")][2]
    for (loop, backend), (sim, res, seconds, n_events) in results.items():
        if (loop, backend) == ("array", "python"):
            _assert_parity(res, results[("legacy", "python")][1])
        err = 0.0
        corpus = None
        if backend != "python":
            for d, p, c, rates in sim.fluid.samples:
                gold = fluid.fill_python(np.asarray(d, dtype=float), p, c)
                if len(gold):
                    err = max(err, float(np.max(np.abs(rates - gold))))
            corpus = sim.fluid.corpus_stats.as_dict()
            emit(f"dynamic_corpus_{backend}",
                 sim.fluid.corpus_stats.flow_occupancy * 100.0,
                 f"flow_occupancy_pct;buckets={corpus['buckets']};"
                 f"link_occupancy={corpus['link_occupancy']:.3f}")
        name = f"dynamic_loop_{loop}_{backend}"
        speedup = legacy_s / seconds if seconds > 0 else math.inf
        prof = res.profile
        record_dynamic_row(
            name=name, loop=loop, backend=backend, n_jobs=n_jobs,
            n_events=n_events, ticks=prof.ticks, seconds=seconds,
            speedup_vs_legacy=speedup, max_abs_err_vs_oracle=err,
            profile=prof.as_dict(), corpus=corpus)
        emit(name, seconds * 1e6 / max(1, prof.ticks),
             f"n_jobs={n_jobs};seconds={seconds:.2f};"
             f"speedup={speedup:.1f}x;max_abs_err={err:.3g}")
        _emit_profile(name, prof)

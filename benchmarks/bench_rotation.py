"""Fabric-wide joint rotation planner vs the legacy per-link tie-break.

Two comparisons (see DESIGN.md section 13):

  * **Scheme quality on J1** — the oracle snapshot where per-link rotation
    solves provably conflict (host-optimal shift infeasible on the shared
    uplink).  ``rotation_joint=False`` reproduces the pre-planner
    "uplinks take precedence" reconciliation; we report the worst per-link
    planning-demand score of the final global offsets (joint: 100 = every
    link feasible; legacy: < 100 = a host link stays oversubscribed in
    time) and the resulting JCT delta of the squeezed low-priority job.

  * **Planner wall-time at F4 scale** — the Score-phase solve of the F4
    uplink component (3 jobs x 2 contended links, 5184 rotation combos):
    the legacy per-link pipeline (one ``find_feasible_rotation`` per link,
    per-combo Python run scan) vs the planner's batched multi-link path
    (stacked (L, R, S) banks through ``kernels.ops.score_multilink`` —
    compiled Pallas on TPU, jit'd jnp reference elsewhere — plus the
    vectorized run scan).  The derived field reports the speedup; the
    acceptance bar is >= 5x.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.metronome_testbed import make_snapshot, snapshot_scenario
from repro.core import geometry, rotation, scoring
from repro.core.contention import LinkView
from repro.core.controller import StopAndWaitController
from repro.core.experiment import Policy
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.topology import is_uplink

from . import common
from .common import Timer, emit

# the joint planner vs the pre-planner "uplinks take precedence" ablation
J1_POLICIES = (
    Policy("metronome", label="joint"),
    Policy("metronome", rotation_joint=False, label="legacy"),
)


def _worst_planning_score(cluster, registry, ctrl) -> float:
    """Worst per-link Eq. 18 score of the controller's FINAL global offsets
    under the planning demand view — the fabric-feasibility check."""
    view = LinkView.from_registry(cluster, registry)
    worst = 100.0
    for lid, st in ctrl.links.items():
        sch = st.scheme
        duties, _ = view.recalc_traffic(lid, sch.jobs, sch.muls, sch.base_ms)
        pats = geometry.pattern_matrix(sch.muls, duties, ctrl.di_pre)
        shifts = np.array([
            geometry.delay_to_shift_slots(ctrl.job_offset_ms(j), sch.base_ms,
                                          ctrl.di_pre)
            for j in sch.jobs
        ])
        groups = view.link_groups(lid)
        bws = [sum(t.traffic.bw_gbps for t in groups.get(j, []))
               for j in sch.jobs]
        worst = min(worst, float(scoring.score_combos(
            pats, np.asarray(bws), cluster.link_alloc(lid),
            shifts[None, :])[0]))
    return worst


def _schedule(sid: str, joint: bool, n_iterations: int):
    cluster, wls, bg = make_snapshot(sid, n_iterations=n_iterations)
    ctrl = StopAndWaitController(joint=joint)
    fw = SchedulingFramework(cluster, MetronomePlugin(controller=ctrl,
                                                      joint=joint))
    for wl in wls:
        fw.schedule_workload(wl)
    ctrl.run_offline_recalculation(fw.registry, cluster)
    return cluster, fw, ctrl, wls


def _bench_j1() -> None:
    n_iter = common.pick(300, 25)
    cfg = common.bench_cfg(jitter_std=0.02)
    scn = snapshot_scenario("J1", n_iterations=n_iter)
    with Timer() as t:
        sw = common.run_sweep([scn], J1_POLICIES, cfg, origin="rotation")
    for pol in J1_POLICIES:
        # fabric feasibility of the final offsets (planner-internal view)
        cluster, fw, ctrl, _ = _schedule("J1", pol.rotation_joint, n_iter)
        feas = _worst_planning_score(cluster, fw.registry, ctrl)
        r = sw.get("J1", pol.name)
        emit(f"rotation_J1_{pol.name}", t.us / len(J1_POLICIES),
             f"worst_link_score={feas:.2f};"
             f"lo_jct_s={r.sim.finish_times_ms.get('j1-local', np.nan)/1e3:.2f};"
             f"tct_s={r.sim.total_completion_ms/1e3:.2f}")
    lo_j = sw.get("J1", "joint").sim.finish_times_ms.get("j1-local", np.nan)
    lo_l = sw.get("J1", "legacy").sim.finish_times_ms.get("j1-local", np.nan)
    delta = 100.0 * (1.0 - lo_j / lo_l) if lo_l else float("nan")
    emit("rotation_J1_joint_vs_legacy", 0.0,
         f"lo_jct_saving_pct={delta:.2f}")


def _bench_planner_walltime() -> None:
    """Batched multi-link solve vs the per-link Python loop, F4 scale."""
    cluster, fw, ctrl, _ = _schedule("F4", True, common.pick(300, 25))
    view = LinkView.from_registry(cluster, fw.registry)
    links = [l for l in view.planning_links() if is_uplink(l)]
    reps = common.pick(20, 3)

    def loop_path():
        # the legacy Score-phase pipeline: one independent per-link solve
        # (find_feasible_rotation's per-combo Python scan) per link
        out = []
        for lid in links:
            out.append(rotation.solve_link(view, fw.registry, lid,
                                           mode="fast"))
        return out

    def batched_path():
        return rotation.joint_solve(view, fw.registry, links, mode="fast",
                                    backend="kernel")

    loop_path(), batched_path()  # warmup (jit cache for the kernel path)
    t0 = time.perf_counter()
    for _ in range(reps):
        loop_path()
    t_loop = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        res = batched_path()
    t_batched = (time.perf_counter() - t0) / reps * 1e6
    speedup = t_loop / t_batched if t_batched else float("inf")
    emit("rotation_planner_loop_F4", t_loop,
         f"links={len(links)};combos=5184")
    emit("rotation_planner_batched_F4", t_batched,
         f"links={len(links)};score={res.score:.2f};"
         f"speedup_vs_loop={speedup:.1f}x")


def run() -> None:
    _bench_j1()
    _bench_planner_walltime()


if __name__ == "__main__":
    run()

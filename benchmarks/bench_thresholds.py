"""Fig. 14 (O_T / A_T monitor thresholds) and Fig. 15 (G_T / E_T period
approximation via idle injection on snapshot 3).

The controller thresholds ride on ``Policy.options`` (scheduler-specific
options forwarded to ``StopAndWaitController``), so the sweep is a plain
policy grid instead of a hand-rolled framework/simulator pipeline."""
from __future__ import annotations

from repro.configs.metronome_testbed import MODEL_FLEET, snapshot_scenario
from repro.core.experiment import Policy
from repro.core.experiment import run as run_cell
from repro.core.simulator import SimConfig

from . import common
from .common import Timer, emit


def _cfg(jitter: float = 0.02) -> SimConfig:
    return SimConfig(duration_ms=common.pick(150_000, 15_000), seed=3,
                     jitter_std=jitter)


def _threshold_policy(a_t: float, o_t: int) -> Policy:
    return Policy("metronome").with_options(a_t=a_t, o_t=o_t)


def run() -> None:
    # --- Fig. 14: A_T x O_T flame chart over S1..S5 -------------------------
    for sid in common.pick(("S1", "S2", "S3"), ("S2",)):
        scn = snapshot_scenario(sid, n_iterations=common.pick(400, 30))
        policies = [_threshold_policy(a_t, o_t)
                    for o_t in common.pick((3, 5), (5,))
                    for a_t in common.pick((1.05, 1.10, 1.15), (1.10,))]
        with Timer() as t:
            sw = common.run_sweep([scn], policies, _cfg(),
                                  origin="thresholds")
        rows = []
        for pol in policies:
            res = sw.get(sid, pol.name)
            opts = pol.scheduler_options()
            rows.append((opts["a_t"], opts["o_t"],
                         res.mean_s_per_1000(res.low_priority),
                         res.sim.readjustments))
        best = min(r[2] for r in rows)
        for a_t, o_t, lo_t, readj in rows:
            emit(f"fig14_{sid}_AT{int(a_t*100)}_OT{o_t}",
                 t.us / len(policies),
                 f"lo_increase_pct={100*(lo_t/best-1):.2f};readj={readj}")

    # --- Fig. 15: period-gap sweep on S3 (G_T / E_T) ------------------------
    wrn = dict(MODEL_FLEET["FT-WideResNet101"])
    vgg = dict(MODEL_FLEET["FT-VGG19-S3"])
    # benchmark: exactly commensurate 2:1 periods
    gaps = common.pick((35.0, 30.0, 20.0, 10.0, 5.0, 0.0), (35.0, 0.0))
    pol = _threshold_policy(1.10, 5)
    ref_lo = ref_hi = None
    for gap in gaps:
        MODEL_FLEET["FT-WideResNet101"] = dict(
            wrn, period_ms=vgg["period_ms"] / 2 - gap)
        try:
            scn = snapshot_scenario("S3", n_iterations=common.pick(400, 30))
            with Timer() as t:
                res = run_cell(scn, pol, _cfg())
            lo_t = res.mean_s_per_1000(res.low_priority)
            hi_t = res.mean_s_per_1000(res.high_priority)
            if gap == 0.0:
                ref_lo, ref_hi = lo_t, hi_t
            emit(f"fig15_gap{int(gap)}ms", t.us,
                 f"lo_s_per_1000={lo_t:.2f};hi_s_per_1000={hi_t:.2f}")
        finally:
            MODEL_FLEET["FT-WideResNet101"] = wrn
    if ref_lo:
        emit("fig15_benchmark", 0.0,
             f"lo_ref={ref_lo:.2f};hi_ref={ref_hi:.2f}")


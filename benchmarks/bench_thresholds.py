"""Fig. 14 (O_T / A_T monitor thresholds) and Fig. 15 (G_T / E_T period
approximation via idle injection on snapshot 3)."""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import MODEL_FLEET, make_snapshot
from repro.core.controller import StopAndWaitController
from repro.core.framework import SchedulingFramework
from repro.core.harness import priority_split
from repro.core.scheduler import MetronomePlugin
from repro.core.simulator import ClusterSimulator, SimConfig

from . import common
from .common import Timer, emit


def _run_with(sid: str, a_t: float, o_t: int, jitter: float = 0.02):
    cluster, wls, bg = make_snapshot(sid, n_iterations=common.pick(400, 30))
    ctrl = StopAndWaitController(a_t=a_t, o_t=o_t)
    fw = SchedulingFramework(cluster, MetronomePlugin(controller=ctrl))
    jobs = []
    for wl in wls:
        fw.schedule_workload(wl)
        jobs.extend(wl.jobs)
    ctrl.run_offline_recalculation(fw.registry, cluster)
    sim = ClusterSimulator(cluster, jobs,
                           SimConfig(duration_ms=common.pick(150_000, 15_000),
                                     seed=3, jitter_std=jitter),
                           controller=ctrl, background=bg,
                           registry=fw.registry)
    res = sim.run()
    return res, wls


def run() -> None:
    # --- Fig. 14: A_T x O_T flame chart over S1..S5 -------------------------
    for sid in common.pick(("S1", "S2", "S3"), ("S2",)):
        base = None
        rows = []
        for o_t in common.pick((3, 5), (5,)):
            for a_t in common.pick((1.05, 1.10, 1.15), (1.10,)):
                with Timer() as t:
                    res, wls = _run_with(sid, a_t, o_t)
                hi, lo = priority_split(wls)
                lo_t = np.mean([res.time_per_1000_iters_s[j] for j in lo]) \
                    if lo else float("nan")
                rows.append((a_t, o_t, lo_t, res.readjustments, t.us))
        best = min(r[2] for r in rows)
        for a_t, o_t, lo_t, readj, us in rows:
            emit(f"fig14_{sid}_AT{int(a_t*100)}_OT{o_t}", us,
                 f"lo_increase_pct={100*(lo_t/best-1):.2f};readj={readj}")

    # --- Fig. 15: period-gap sweep on S3 (G_T / E_T) ------------------------
    wrn = dict(MODEL_FLEET["FT-WideResNet101"])
    vgg = dict(MODEL_FLEET["FT-VGG19-S3"])
    # benchmark: exactly commensurate 2:1 periods
    gaps = common.pick((35.0, 30.0, 20.0, 10.0, 5.0, 0.0), (35.0, 0.0))
    ref_lo = ref_hi = None
    for gap in gaps:
        MODEL_FLEET["FT-WideResNet101"] = dict(
            wrn, period_ms=vgg["period_ms"] / 2 - gap)
        try:
            with Timer() as t:
                res, wls = _run_with("S3", 1.10, 5)
            hi, lo = priority_split(wls)
            lo_t = np.mean([res.time_per_1000_iters_s[j] for j in lo])
            hi_t = np.mean([res.time_per_1000_iters_s[j] for j in hi])
            if gap == 0.0:
                ref_lo, ref_hi = lo_t, hi_t
            emit(f"fig15_gap{int(gap)}ms", t.us,
                 f"lo_s_per_1000={lo_t:.2f};hi_s_per_1000={hi_t:.2f}")
        finally:
            MODEL_FLEET["FT-WideResNet101"] = wrn
    if ref_lo:
        emit("fig15_benchmark", 0.0,
             f"lo_ref={ref_lo:.2f};hi_ref={ref_hi:.2f}")

"""Shared helpers for the per-table benchmarks.

Benches run their experiment grids through the Scenario/Policy sweep API
(``repro.core.experiment``).  Every sweep executed via :func:`run_sweep` is
recorded in-process; ``benchmarks/run.py --sweep-out`` persists the merged
record as schema-versioned ``BENCH_sweep.json`` (uploaded + validated in
CI), so the perf/result trajectory of every bench is a machine-readable
artifact instead of stdout-only CSV rows.
"""
from __future__ import annotations

import sys
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.configs.metronome_testbed import snapshot_scenario
from repro.core.experiment import Policy, Scenario, sweep
from repro.core.results import (SweepResult, to_bench_dict,
                                to_dynamic_throughput_dict,
                                to_robustness_dict, to_timing_dict,
                                to_trace_throughput_dict)
from repro.core.simulator import SimConfig

SCHEDULER_NAMES = ("metronome", "default", "diktyo", "ideal")
POLICIES = tuple(Policy(scheduler=s) for s in SCHEDULER_NAMES)

BENCH_CFG = SimConfig(duration_ms=150_000.0, seed=3, jitter_std=0.01)

# --smoke mode (benchmarks/run.py --smoke, exercised by CI): every bench
# runs end-to-end with tiny iteration counts / durations so the scripts
# cannot rot silently.  The flag is set BEFORE any run() executes; benches
# read it at call time via pick().
SMOKE = False

# guards every RECORDED_* recorder below: benches running cells on a
# thread pool (run.py --workers N) record from worker threads, and the
# emit()/record_*_row() read-modify-write patterns interleave without it
_RECORD_LOCK = threading.Lock()

# every sweep any bench ran this process (run.py --sweep-out persists it)
RECORDED_SWEEPS: List[SweepResult] = []

# every emit() row any bench printed this process (run.py --bench-out
# persists the merged record as schema-versioned BENCH_sched_time.json);
# CURRENT_ORIGIN is maintained by run.py around each bench module
RECORDED_EMITS: List[Dict[str, object]] = []
CURRENT_ORIGIN = ""

# every trace-throughput row bench_trace_throughput recorded this process
# (run.py --trace-out persists the merged record as schema-versioned
# BENCH_trace_throughput.json)
RECORDED_TRACE_ROWS: List[Dict[str, object]] = []

# every dynamic-throughput row bench_dynamic_throughput recorded this
# process (run.py --dynamic-out persists the merged record as
# schema-versioned BENCH_dynamic_throughput.json)
RECORDED_DYNAMIC_ROWS: List[Dict[str, object]] = []

# every graceful-degradation row bench_robustness recorded this process
# (run.py --robustness-out persists the merged record as schema-versioned
# BENCH_robustness.json)
RECORDED_ROBUSTNESS_ROWS: List[Dict[str, object]] = []

# parallel sweep execution (run.py --workers / --worker-mode): run_sweep
# fans independent grid cells over a thread or process pool; 1/thread =
# the historical serial path
WORKERS = 1
WORKER_MODE = "thread"

# content-keyed sweep cache (run.py --cache-dir, the nightly CI job):
# run_sweep consults/updates it when set; None = always compute
CACHE_DIR: Optional[str] = None


def pick(default, smoke_value):
    """``default`` normally, ``smoke_value`` under ``run.py --smoke``."""
    return smoke_value if SMOKE else default


def bench_cfg(**overrides) -> SimConfig:
    """The standard bench SimConfig, smoke-shrunk when --smoke is active."""
    cfg = SimConfig(duration_ms=pick(150_000.0, 15_000.0), seed=3,
                    jitter_std=0.01)
    for k, v in overrides.items():
        setattr(cfg, k, v)
    return cfg


def run_sweep(scenarios: Sequence[Scenario], policies: Sequence[Policy],
              cfg: Optional[SimConfig] = None, *, origin: str,
              strict: bool = True) -> SweepResult:
    """Run a grid through ``experiment.sweep`` and record it for the
    ``BENCH_sweep.json`` artifact.

    ``strict=True`` (the bench default) re-raises after recording if any
    cell failed, so a broken bench still fails run.py loudly — the
    isolation lives in the artifact, which keeps the healthy cells.

    With ``CACHE_DIR`` set (run.py --cache-dir, the nightly CI job) the
    grid is keyed on its *materialized content* (``benchmarks.cache``) and
    an unchanged grid is restored from disk instead of re-simulated."""
    key = None
    if CACHE_DIR is not None:
        from . import cache as _cache

        key = "sweep-" + _cache.fingerprint_grid(scenarios, policies, cfg)
        hit = _cache.load(CACHE_DIR, key)
        if hit is not None:
            hit.meta.update(origin=origin, smoke=SMOKE, cache="hit")
            with _RECORD_LOCK:
                RECORDED_SWEEPS.append(hit)
            if strict and hit.errors:
                bad = ", ".join(f"({c.scenario}, {c.policy})"
                                for c in hit.errors)
                raise RuntimeError(f"sweep cells failed in {origin}: {bad}")
            return hit
    sw = sweep(scenarios, policies, cfg, workers=WORKERS, mode=WORKER_MODE)
    sw.meta.update(origin=origin, smoke=SMOKE, workers=WORKERS)
    if key is not None and not sw.errors:
        from . import cache as _cache

        sw.meta.update(cache="miss")
        _cache.store(CACHE_DIR, key, sw)
    with _RECORD_LOCK:
        RECORDED_SWEEPS.append(sw)
    if strict and sw.errors:
        bad = ", ".join(f"({c.scenario}, {c.policy})" for c in sw.errors)
        for c in sw.errors:
            print(c.error, file=sys.stderr)
        raise RuntimeError(f"sweep cells failed in {origin}: {bad}")
    return sw


def snapshot_sweep(sid: str, n_iterations: Optional[int] = None,
                   cfg: Optional[SimConfig] = None,
                   policies: Sequence[Policy] = POLICIES, *,
                   origin: str) -> SweepResult:
    """One snapshot under every policy (each cell re-materializes the
    snapshot, so runs never share mutated Job objects).  The old
    ``run_snapshot_all`` dict — and its ``"_workloads"`` magic key — is
    replaced by the typed :class:`SweepResult` (priority splits live on
    each :class:`ExperimentResult`)."""
    if n_iterations is None:
        n_iterations = pick(400, 30)
    if cfg is None:
        cfg = bench_cfg()
    scn = snapshot_scenario(sid, n_iterations=n_iterations)
    return run_sweep([scn], policies, cfg, origin=origin)


def write_sweeps(path: str) -> None:
    """Persist every recorded sweep as schema-versioned BENCH_sweep.json."""
    import json

    with open(path, "w") as f:
        json.dump(to_bench_dict(RECORDED_SWEEPS, smoke=SMOKE), f, indent=1,
                  allow_nan=False)


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV rows, also
    recorded in-process for the BENCH_sched_time.json timing artifact."""
    print(f"{name},{us_per_call:.1f},{derived}")
    with _RECORD_LOCK:
        RECORDED_EMITS.append(
            {"name": name, "us_per_call": float(us_per_call),
             "derived": derived, "origin": CURRENT_ORIGIN})


def write_timings(path: str) -> None:
    """Persist every recorded emit() row as schema-versioned timing JSON
    (the BENCH_sched_time.json trajectory artifact)."""
    import json

    with open(path, "w") as f:
        json.dump(to_timing_dict(RECORDED_EMITS, smoke=SMOKE), f, indent=1,
                  allow_nan=False)


def record_trace_row(**row: object) -> None:
    """Record one trace-throughput row (see
    ``results.to_trace_throughput_dict`` for the field contract); run.py
    ``--trace-out`` persists the merged record."""
    row.setdefault("origin", CURRENT_ORIGIN)
    with _RECORD_LOCK:
        RECORDED_TRACE_ROWS.append(row)


def write_trace_throughput(path: str) -> None:
    """Persist every recorded trace-throughput row as schema-versioned
    JSON (the BENCH_trace_throughput.json artifact)."""
    import json

    with open(path, "w") as f:
        json.dump(to_trace_throughput_dict(RECORDED_TRACE_ROWS, smoke=SMOKE),
                  f, indent=1, allow_nan=False)


def record_dynamic_row(**row: object) -> None:
    """Record one dynamic-throughput row (see
    ``results.to_dynamic_throughput_dict`` for the field contract); run.py
    ``--dynamic-out`` persists the merged record."""
    row.setdefault("origin", CURRENT_ORIGIN)
    with _RECORD_LOCK:
        RECORDED_DYNAMIC_ROWS.append(row)


def write_dynamic_throughput(path: str) -> None:
    """Persist every recorded dynamic-throughput row as schema-versioned
    JSON (the BENCH_dynamic_throughput.json artifact)."""
    import json

    with open(path, "w") as f:
        json.dump(
            to_dynamic_throughput_dict(RECORDED_DYNAMIC_ROWS, smoke=SMOKE),
            f, indent=1, allow_nan=False)


def record_robustness_row(**row: object) -> None:
    """Record one graceful-degradation row (see
    ``results.to_robustness_dict`` for the field contract); run.py
    ``--robustness-out`` persists the merged record."""
    row.setdefault("origin", CURRENT_ORIGIN)
    with _RECORD_LOCK:
        RECORDED_ROBUSTNESS_ROWS.append(row)


def write_robustness(path: str) -> None:
    """Persist every recorded graceful-degradation row as schema-versioned
    JSON (the BENCH_robustness.json artifact)."""
    import json

    with open(path, "w") as f:
        json.dump(to_robustness_dict(RECORDED_ROBUSTNESS_ROWS, smoke=SMOKE),
                  f, indent=1, allow_nan=False)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6

"""Shared helpers for the per-table benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.configs.metronome_testbed import SNAPSHOTS, make_snapshot
from repro.core.harness import RunResult, priority_split, run_experiment
from repro.core.simulator import SimConfig

SCHEDULERS = ("metronome", "default", "diktyo", "ideal")

BENCH_CFG = SimConfig(duration_ms=150_000.0, seed=3, jitter_std=0.01)

# --smoke mode (benchmarks/run.py --smoke, exercised by CI): every bench
# runs end-to-end with tiny iteration counts / durations so the scripts
# cannot rot silently.  The flag is set BEFORE any run() executes; benches
# read it at call time via pick().
SMOKE = False


def pick(default, smoke_value):
    """``default`` normally, ``smoke_value`` under ``run.py --smoke``."""
    return smoke_value if SMOKE else default


def bench_cfg(**overrides) -> SimConfig:
    """The standard bench SimConfig, smoke-shrunk when --smoke is active."""
    cfg = SimConfig(duration_ms=pick(150_000.0, 15_000.0), seed=3,
                    jitter_std=0.01)
    for k, v in overrides.items():
        setattr(cfg, k, v)
    return cfg


def run_snapshot_all(sid: str, n_iterations: Optional[int] = None,
                     cfg: Optional[SimConfig] = None,
                     schedulers=SCHEDULERS, **kw) -> Dict[str, RunResult]:
    """Run one snapshot under every scheduler.

    Scheduler names key the :class:`RunResult`s; the single non-result key
    ``"_workloads"`` holds the workload list of the FIRST scheduler's run
    (every run regenerates structurally identical workloads from the same
    snapshot, so one representative list is unambiguous — job names and
    priorities are what callers consume)."""
    if n_iterations is None:
        n_iterations = pick(400, 30)
    if cfg is None:
        cfg = bench_cfg()
    out: Dict[str, RunResult] = {}
    wls_rep = None
    for sched in schedulers:
        cluster, wls, bg = make_snapshot(sid, n_iterations=n_iterations)
        out[sched] = run_experiment(sched, cluster, wls, cfg, background=bg,
                                    **kw)
        if wls_rep is None:
            wls_rep = wls
    out["_workloads"] = wls_rep
    return out


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6

"""Fig. 11 (bandwidth/duty change via batch-size halving) and
Fig. 12 (latency parameter sweep)."""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import make_snapshot
from repro.core.events import TrafficChange
from repro.core.experiment import Policy, Scenario

from . import common
from .common import Timer, emit

POLICIES = tuple(Policy(s) for s in ("metronome", "default", "diktyo"))


def _s1_scenario(label: str, halve_batch: bool, n_iter: int) -> Scenario:
    """S1, optionally with every job's duty rising 1.4x mid-run (the
    batch-size-halving traffic change of Fig. 11) as typed events."""

    def build():
        cluster, wls, bg = make_snapshot("S1", n_iterations=n_iter)
        events = []
        if halve_batch:
            t_on = common.pick(30_000.0, 5_000.0)
            events = [TrafficChange(t_on, j.name, 1.4)
                      for wl in wls for j in wl.jobs]
        return cluster, wls, bg, events
    return Scenario(name=f"S1-{label}", build=build)


def _tau_scenario(sid: str, tau: float, n_iter: int) -> Scenario:
    """S4/S5 with the congested node's latency parameter overridden."""

    def build():
        cluster, wls, bg = make_snapshot(sid, n_iterations=n_iter)
        for other in cluster.node_names:
            if other != "worker-a30-2":
                cluster.set_latency("worker-a30-2", other, tau)
        return cluster, wls, bg
    return Scenario(name=f"{sid}-tau{int(tau)}", build=build)


def _accel(sw, scn_name: str, other: str) -> float:
    me = sw.get(scn_name, "metronome")
    o = sw.get(scn_name, other)
    both = sorted(set(me.sim.time_per_1000_iters_s)
                  & set(o.sim.time_per_1000_iters_s))
    return 100.0 * (1 - np.mean([me.sim.time_per_1000_iters_s[j]
                                 for j in both])
                    / np.mean([o.sim.time_per_1000_iters_s[j]
                               for j in both]))


def run() -> None:
    cfg = common.bench_cfg()
    n_iter = common.pick(400, 30)
    # --- Fig. 11: halve the batch size of all S1 jobs at t=30s -> duty up ---
    for label, halved in (("orig", False), ("halved_batch", True)):
        scn = _s1_scenario(label, halved, n_iter)
        with Timer() as t:
            sw = common.run_sweep([scn], POLICIES, cfg,
                                  origin="param_variation")
        for other in ("default", "diktyo"):
            me = sw.get(scn.name, "metronome")
            o = sw.get(scn.name, other)
            emit(f"fig11_{label}_accel_vs_{other}", t.us / len(POLICIES),
                 f"accel_pct={_accel(sw, scn.name, other):.2f};"
                 f"gamma_me={me.sim.avg_bw_utilization:.4f};"
                 f"gamma_other={o.sim.avg_bw_utilization:.4f}")

    # --- Fig. 12: sweep the congestion latency parameter on S4/S5 ----------
    for sid in ("S4", "S5"):
        scenarios = [_tau_scenario(sid, tau, common.pick(300, 25))
                     for tau in common.pick((10.0, 40.0, 80.0), (40.0,))]
        with Timer() as t:
            sw = common.run_sweep(scenarios, POLICIES, cfg,
                                  origin="param_variation")
        for scn in scenarios:
            for other in ("default", "diktyo"):
                emit(f"fig12_{scn.name.replace(f'{sid}-', f'{sid}_')}"
                     f"_vs_{other}",
                     t.us / (len(scenarios) * len(POLICIES)),
                     f"accel_pct={_accel(sw, scn.name, other):.2f}")

"""Fig. 11 (bandwidth/duty change via batch-size halving) and
Fig. 12 (latency parameter sweep)."""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import make_snapshot
from repro.core.harness import priority_split, run_experiment
from repro.core.simulator import SimConfig

from . import common
from .common import Timer, emit


def run() -> None:
    cfg = common.bench_cfg()
    n_iter = common.pick(400, 30)
    # --- Fig. 11: halve the batch size of all S1 jobs at t=30s -> duty up ---
    for label, changes in (("orig", ()),
                           ("halved_batch", (("t", None, 1.4),))):
        results = {}
        for sched in ("metronome", "default", "diktyo"):
            cluster, wls, bg = make_snapshot("S1", n_iterations=n_iter)
            tc = []
            if changes:
                t_on = common.pick(30_000.0, 5_000.0)
                tc = [(t_on, j.name, 1.4) for wl in wls for j in wl.jobs]
            with Timer() as t:
                results[sched] = run_experiment(
                    sched, cluster, wls, cfg, background=bg,
                    traffic_changes=tc)
        me = results["metronome"]
        for other in ("default", "diktyo"):
            o = results[other]
            both = set(me.sim.time_per_1000_iters_s) & set(
                o.sim.time_per_1000_iters_s)
            acc = 100.0 * (1 - np.mean([me.sim.time_per_1000_iters_s[j]
                                        for j in both])
                           / np.mean([o.sim.time_per_1000_iters_s[j]
                                      for j in both]))
            emit(f"fig11_{label}_accel_vs_{other}", t.us,
                 f"accel_pct={acc:.2f};"
                 f"gamma_me={me.sim.avg_bw_utilization:.4f};"
                 f"gamma_other={o.sim.avg_bw_utilization:.4f}")

    # --- Fig. 12: sweep the congestion latency parameter on S4/S5 ----------
    for sid in ("S4", "S5"):
        for tau in common.pick((10.0, 40.0, 80.0), (40.0,)):
            results = {}
            for sched in ("metronome", "default", "diktyo"):
                cluster, wls, bg = make_snapshot(
                    sid, n_iterations=common.pick(300, 25))
                for other in cluster.node_names:
                    if other != "worker-a30-2":
                        cluster.set_latency("worker-a30-2", other, tau)
                with Timer() as t:
                    results[sched] = run_experiment(
                        sched, cluster, wls, cfg, background=bg)
            me = results["metronome"]
            for other in ("default", "diktyo"):
                o = results[other]
                both = set(me.sim.time_per_1000_iters_s)
                acc = 100.0 * (1 - np.mean(
                    [me.sim.time_per_1000_iters_s[j] for j in both])
                    / np.mean([o.sim.time_per_1000_iters_s[j] for j in both]))
                emit(f"fig12_{sid}_tau{int(tau)}_vs_{other}", t.us,
                     f"accel_pct={acc:.2f}")

"""Beyond-paper: schedulers across leaf–spine oversubscription ratios.

Sweeps the fabric from the paper's 1:1 assumption (uplinks never the
bottleneck — Eq. 14's simplification) to 4:1 oversubscription, on the F2
workload shape (two 4-task jobs spanning two leaves). Host links never
contend (24G of 25G); every slowdown is uplink contention that the seed's
host-link-only model could not see.

Emits, per (ratio, scheduler): avg JCT, mean time/1000 iters, max uplink
utilization, and Metronome's JCT gain over Default per ratio.
"""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import FABRIC_SNAPSHOTS, make_snapshot
from repro.core.cluster import make_fabric_cluster
from repro.core.harness import run_experiment
from repro.core.simulator import SimConfig

from . import common
from .common import Timer, emit

RATIOS = (1.0, 2.0, 4.0)
SCHEDULERS = ("metronome", "default", "diktyo", "ideal")


def _cfg() -> SimConfig:
    return SimConfig(duration_ms=common.pick(120_000.0, 15_000.0), seed=3,
                     jitter_std=0.01)


def _f2_workloads(n_iterations=None):
    """The F2 snapshot's workload pair (single source of truth for the
    spec lives in configs.metronome_testbed); only the cluster varies
    across the oversubscription sweep."""
    if n_iterations is None:
        n_iterations = common.pick(300, 25)
    _, wls, _ = make_snapshot("F2", n_iterations=n_iterations)
    return wls


def _avg_jct_ms(res) -> float:
    fin = [v for v in res.sim.finish_times_ms.values() if not np.isnan(v)]
    return float(np.mean(fin)) if fin else float("nan")


def run() -> None:
    cfg = _cfg()
    for ratio in common.pick(RATIOS, (2.0,)):
        results = {}
        for sched in SCHEDULERS:
            cluster = make_fabric_cluster(n_leaves=2, hosts_per_leaf=2,
                                          bw_gbps=25.0,
                                          oversubscription=ratio)
            wls = _f2_workloads()
            with Timer() as t:
                results[sched] = run_experiment(sched, cluster, wls, cfg)
            r = results[sched]
            uplink = max(r.sim.uplink_utilization.values(), default=0.0)
            iters = [v for v in r.sim.time_per_1000_iters_s.values()
                     if not np.isnan(v)]
            emit(f"fabric_{ratio:g}to1_{sched}", t.us,
                 f"avg_jct_s={_avg_jct_ms(r) / 1e3:.2f};"
                 f"s_per_1000={np.mean(iters):.2f};"
                 f"uplink_util={uplink:.3f}")
        me, de = _avg_jct_ms(results["metronome"]), _avg_jct_ms(results["default"])
        gain = 100.0 * (1.0 - me / de) if de else float("nan")
        emit(f"fabric_{ratio:g}to1_metronome_gain", 0.0,
             f"jct_gain_vs_default_pct={gain:.2f}")
    # the shipped fabric snapshots end-to-end (F2: 2:1, F4: 4:1, 3 jobs)
    for sid in FABRIC_SNAPSHOTS:
        for sched in ("metronome", "default"):
            cluster, wls, bg = make_snapshot(
                sid, n_iterations=common.pick(300, 25))
            with Timer() as t:
                r = run_experiment(sched, cluster, wls, cfg, background=bg)
            uplink = max(r.sim.uplink_utilization.values(), default=0.0)
            emit(f"fabric_{sid}_{sched}", t.us,
                 f"avg_jct_s={_avg_jct_ms(r) / 1e3:.2f};"
                 f"uplink_util={uplink:.3f};readj={r.sim.readjustments}")

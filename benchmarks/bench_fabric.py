"""Beyond-paper: schedulers across leaf–spine oversubscription ratios.

Sweeps the fabric from the paper's 1:1 assumption (uplinks never the
bottleneck — Eq. 14's simplification) to 4:1 oversubscription, on the F2
workload shape (two 4-task jobs spanning two leaves). Host links never
contend (24G of 25G); every slowdown is uplink contention that the seed's
host-link-only model could not see.

Emits, per (ratio, scheduler): avg JCT, mean time/1000 iters, max uplink
utilization, and Metronome's JCT gain over Default per ratio.
"""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import (FABRIC_SNAPSHOTS, make_snapshot,
                                             snapshot_scenario)
from repro.core.cluster import make_fabric_cluster
from repro.core.experiment import Policy, Scenario
from repro.core.simulator import SimConfig

from . import common
from .common import POLICIES, Timer, emit

RATIOS = (1.0, 2.0, 4.0)


def _cfg() -> SimConfig:
    return SimConfig(duration_ms=common.pick(120_000.0, 15_000.0), seed=3,
                     jitter_std=0.01)


def _ratio_scenario(ratio: float) -> Scenario:
    """The F2 workload pair (single source of truth for the spec lives in
    configs.metronome_testbed) on a fabric with the given oversubscription
    ratio; only the cluster varies across the sweep."""

    def build(ratio=ratio):
        cluster = make_fabric_cluster(n_leaves=2, hosts_per_leaf=2,
                                      bw_gbps=25.0, oversubscription=ratio)
        _, wls, _ = make_snapshot("F2", n_iterations=common.pick(300, 25))
        return cluster, wls
    return Scenario(name=f"F2@{ratio:g}to1", build=build)


def run() -> None:
    cfg = _cfg()
    for ratio in common.pick(RATIOS, (2.0,)):
        scn = _ratio_scenario(ratio)
        with Timer() as t:
            sw = common.run_sweep([scn], POLICIES, cfg, origin="fabric")
        for sched in common.SCHEDULER_NAMES:
            r = sw.get(scn.name, sched)
            uplink = max(r.sim.uplink_utilization.values(), default=0.0)
            iters = [v for v in r.sim.time_per_1000_iters_s.values()
                     if not np.isnan(v)]
            emit(f"fabric_{ratio:g}to1_{sched}",
                 t.us / len(common.SCHEDULER_NAMES),
                 f"avg_jct_s={r.mean_jct_ms() / 1e3:.2f};"
                 f"s_per_1000={np.mean(iters):.2f};"
                 f"uplink_util={uplink:.3f}")
        me = sw.get(scn.name, "metronome").mean_jct_ms()
        de = sw.get(scn.name, "default").mean_jct_ms()
        gain = 100.0 * (1.0 - me / de) if de else float("nan")
        emit(f"fabric_{ratio:g}to1_metronome_gain", 0.0,
             f"jct_gain_vs_default_pct={gain:.2f}")
    # the shipped fabric snapshots end-to-end (F2: 2:1, F4: 4:1, 3 jobs)
    scenarios = [snapshot_scenario(sid, n_iterations=common.pick(300, 25))
                 for sid in FABRIC_SNAPSHOTS]
    policies = [Policy("metronome"), Policy("default")]
    with Timer() as t:
        sw = common.run_sweep(scenarios, policies, cfg, origin="fabric")
    for sid in FABRIC_SNAPSHOTS:
        for sched in ("metronome", "default"):
            r = sw.get(sid, sched)
            uplink = max(r.sim.uplink_utilization.values(), default=0.0)
            emit(f"fabric_{sid}_{sched}", t.us / (2 * len(FABRIC_SNAPSHOTS)),
                 f"avg_jct_s={r.mean_jct_ms() / 1e3:.2f};"
                 f"uplink_util={uplink:.3f};readj={r.sim.readjustments}")

"""Beyond-paper: schedulers under mid-run environment fluctuation.

Sweeps the dynamic snapshots (D1: background-flow ramp on a contended host
link; D2: spine-uplink capacity drop at 4:1 oversubscription — see
``configs.metronome_testbed.dynamic_scenario``) over fluctuation amplitude
x policy, including the no-reconfigure ablation (the controller's section
III-C loop disabled: capacity/background changes are handled only by the
A_T/O_T drift monitor — now just ``Policy(reconfigure=False)``).

Emits, per (snapshot, amplitude, policy): high/low-priority avg JCT,
Gamma, readjustment and reconfiguration counts; plus per amplitude the
Metronome JCT gain over Default and the low-priority JCT delta of
reconfiguration vs the ablation.
"""
from __future__ import annotations

from repro.configs.metronome_testbed import (DYNAMIC_SNAPSHOTS,
                                             dynamic_scenario)
from repro.core.experiment import Policy
from repro.core.simulator import SimConfig

from . import common
from .common import Timer, emit

AMPLITUDES = (0.2, 0.3, 0.4)
POLICIES = (
    Policy("metronome"),
    Policy("metronome", reconfigure=False, label="metronome_noreconf"),
    Policy("default"),
)


def run() -> None:
    cfg = SimConfig(duration_ms=common.pick(120_000.0, 20_000.0), seed=3,
                    jitter_std=0.01)
    for sid in DYNAMIC_SNAPSHOTS:
        for amp in common.pick(AMPLITUDES, (0.3,)):
            scn = dynamic_scenario(
                sid, n_iterations=common.pick(300, 25), amplitude=amp,
                t_on_ms=common.pick(15_000.0, 4_000.0),
                t_off_ms=common.pick(45_000.0, 12_000.0))
            with Timer() as t:
                sw = common.run_sweep([scn], POLICIES, cfg, origin="dynamic")
            lo_jct = {}
            for pol in POLICIES:
                r = sw.get(sid, pol.name)
                lo_jct[pol.name] = r.mean_jct_ms(r.low_priority)
                emit(f"dynamic_{sid}_a{amp:g}_{pol.name}",
                     t.us / len(POLICIES),
                     f"hi_jct_s={r.mean_jct_ms(r.high_priority) / 1e3:.2f};"
                     f"lo_jct_s={lo_jct[pol.name] / 1e3:.2f};"
                     f"gamma={r.sim.avg_bw_utilization:.3f};"
                     f"readj={r.sim.readjustments};"
                     f"reconf={r.sim.reconfigurations}")
            me = sw.get(sid, "metronome").mean_jct_ms()
            de = sw.get(sid, "default").mean_jct_ms()
            gain = 100.0 * (1.0 - me / de) if de else float("nan")
            # reconfiguration value: low-priority JCT saved vs the ablation
            saved = 100.0 * (1.0 - lo_jct["metronome"]
                             / lo_jct["metronome_noreconf"])
            emit(f"dynamic_{sid}_a{amp:g}_summary", 0.0,
                 f"jct_gain_vs_default_pct={gain:.2f};"
                 f"reconf_lo_jct_saving_pct={saved:.2f}")


if __name__ == "__main__":
    run()

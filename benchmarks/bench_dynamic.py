"""Beyond-paper: schedulers under mid-run environment fluctuation.

Sweeps the dynamic snapshots (D1: background-flow ramp on a contended host
link; D2: spine-uplink capacity drop at 4:1 oversubscription — see
``configs.metronome_testbed.make_dynamic_snapshot``) over fluctuation
amplitude x scheduler, including the no-reconfigure ablation (the
controller's section III-C loop disabled: capacity/background changes are
handled only by the A_T/O_T drift monitor).

Emits, per (snapshot, amplitude, scheduler): high/low-priority avg JCT,
Gamma, readjustment and reconfiguration counts; plus per amplitude the
Metronome JCT gain over Default and the low-priority JCT delta of
reconfiguration vs the ablation.
"""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import (DYNAMIC_SNAPSHOTS,
                                             make_dynamic_snapshot)
from repro.core.harness import priority_split, run_experiment
from repro.core.simulator import SimConfig

from . import common
from .common import Timer, emit

AMPLITUDES = (0.2, 0.3, 0.4)
# (label, scheduler, reconfigure)
VARIANTS = (
    ("metronome", "metronome", True),
    ("metronome_noreconf", "metronome", False),
    ("default", "default", True),
)



def _jct_ms(res, jobs) -> float:
    fin = [res.sim.finish_times_ms[j] for j in jobs
           if not np.isnan(res.sim.finish_times_ms[j])]
    return float(np.mean(fin)) if fin else float("nan")


def run() -> None:
    cfg = SimConfig(duration_ms=common.pick(120_000.0, 20_000.0), seed=3,
                    jitter_std=0.01)
    for sid in DYNAMIC_SNAPSHOTS:
        for amp in common.pick(AMPLITUDES, (0.3,)):
            results = {}
            lo_jct = {}
            for label, sched, reconf in VARIANTS:
                cluster, wls, bg, evs = make_dynamic_snapshot(
                    sid, n_iterations=common.pick(300, 25), amplitude=amp,
                    t_on_ms=common.pick(15_000.0, 4_000.0),
                    t_off_ms=common.pick(45_000.0, 12_000.0))
                hi, lo = priority_split(wls)
                with Timer() as t:
                    r = run_experiment(sched, cluster, wls, cfg,
                                       background=bg, events=evs,
                                       reconfigure=reconf)
                results[label] = r
                lo_jct[label] = _jct_ms(r, lo)
                emit(f"dynamic_{sid}_a{amp:g}_{label}", t.us,
                     f"hi_jct_s={_jct_ms(r, hi) / 1e3:.2f};"
                     f"lo_jct_s={lo_jct[label] / 1e3:.2f};"
                     f"gamma={r.sim.avg_bw_utilization:.3f};"
                     f"readj={r.sim.readjustments};"
                     f"reconf={r.sim.reconfigurations}")
            all_jobs = lambda r: list(r.sim.finish_times_ms)  # noqa: E731
            me = _jct_ms(results["metronome"], all_jobs(results["metronome"]))
            de = _jct_ms(results["default"], all_jobs(results["default"]))
            gain = 100.0 * (1.0 - me / de) if de else float("nan")
            # reconfiguration value: low-priority JCT saved vs the ablation
            saved = 100.0 * (1.0 - lo_jct["metronome"]
                             / lo_jct["metronome_noreconf"])
            emit(f"dynamic_{sid}_a{amp:g}_summary", 0.0,
                 f"jct_gain_vs_default_pct={gain:.2f};"
                 f"reconf_lo_jct_saving_pct={saved:.2f}")


if __name__ == "__main__":
    run()

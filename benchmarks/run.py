"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each bench_* module for
the paper artifact it reproduces; the mapping lives in DESIGN.md section 7).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (bench_ablation, bench_dynamic, bench_dynamic_throughput,
               bench_fabric, bench_kernels, bench_param_variation,
               bench_persistence, bench_robustness, bench_roofline,
               bench_rotation, bench_sched_time, bench_snapshots, bench_tct,
               bench_thresholds, bench_trace_throughput, common)

ALL = {
    "snapshots": bench_snapshots,     # Fig. 7/8 + Table V
    "fabric": bench_fabric,           # beyond-paper: oversubscribed fabrics
    "dynamic": bench_dynamic,         # beyond-paper: mid-run fluctuation
    "rotation": bench_rotation,       # beyond-paper: joint planner vs legacy
    "tct": bench_tct,                 # Fig. 10
    "param_variation": bench_param_variation,  # Fig. 11/12
    "persistence": bench_persistence,  # Table VI
    "ablation": bench_ablation,       # Tables VII/VIII + Fig. 13
    "thresholds": bench_thresholds,   # Fig. 14/15
    "sched_time": bench_sched_time,   # Fig. 16
    "kernels": bench_kernels,         # kernel micro-benches
    "roofline": bench_roofline,       # dry-run roofline summary
    "trace_throughput": bench_trace_throughput,  # fluid-engine backends @ 10k jobs
    "dynamic_throughput": bench_dynamic_throughput,  # event loops @ 10k-job trace
    "robustness": bench_robustness,   # imperfect telemetry + fault injection
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny iteration counts / durations: every bench "
                         "runs end-to-end fast (CI keeps the scripts alive)")
    ap.add_argument("--sweep-out", default=None, metavar="PATH",
                    help="write every experiment sweep the benches ran as "
                         "schema-versioned JSON (CI: BENCH_sweep.json, "
                         "validated by scripts/validate_bench.py)")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help="write every emit() timing row as schema-versioned "
                         "JSON (CI: BENCH_sched_time.json, validated by "
                         "scripts/validate_bench.py)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the fluid-engine trace-throughput rows as "
                         "schema-versioned JSON (CI nightly: "
                         "BENCH_trace_throughput.json)")
    ap.add_argument("--dynamic-out", default=None, metavar="PATH",
                    help="write the event-loop dynamic-throughput rows as "
                         "schema-versioned JSON (CI nightly: "
                         "BENCH_dynamic_throughput.json)")
    ap.add_argument("--robustness-out", default=None, metavar="PATH",
                    help="write the graceful-degradation rows as "
                         "schema-versioned JSON (CI: BENCH_robustness.json, "
                         "validated by scripts/validate_bench.py)")
    ap.add_argument("--workers", type=int, default=1, metavar="N",
                    help="fan independent sweep cells over N workers "
                         "(results identical to serial; default 1)")
    ap.add_argument("--worker-mode", default="thread",
                    choices=("thread", "process"),
                    help="worker pool flavor for --workers > 1: threads "
                         "(default) or spawned processes (sidesteps the "
                         "GIL for CPU-bound grids; scenario builders are "
                         "picklable dataclasses so cells ship cleanly)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="content-keyed sweep-result cache (nightly CI): "
                         "grids whose materialized inputs are unchanged "
                         "restore from DIR instead of re-simulating")
    args = ap.parse_args()
    if args.smoke:
        common.SMOKE = True
    common.WORKERS = max(1, args.workers)
    common.WORKER_MODE = args.worker_mode
    common.CACHE_DIR = args.cache_dir
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        common.CURRENT_ORIGIN = name
        try:
            ALL[name].run()
        except Exception:  # noqa: BLE001 — keep the harness going
            traceback.print_exc()
            failed.append(name)
        finally:
            common.CURRENT_ORIGIN = ""
    if args.sweep_out:
        common.write_sweeps(args.sweep_out)
        print(f"# wrote {len(common.RECORDED_SWEEPS)} sweeps to "
              f"{args.sweep_out}", file=sys.stderr)
    if args.bench_out:
        common.write_timings(args.bench_out)
        print(f"# wrote {len(common.RECORDED_EMITS)} timing rows to "
              f"{args.bench_out}", file=sys.stderr)
    if args.trace_out:
        common.write_trace_throughput(args.trace_out)
        print(f"# wrote {len(common.RECORDED_TRACE_ROWS)} trace-throughput "
              f"rows to {args.trace_out}", file=sys.stderr)
    if args.dynamic_out:
        common.write_dynamic_throughput(args.dynamic_out)
        print(f"# wrote {len(common.RECORDED_DYNAMIC_ROWS)} "
              f"dynamic-throughput rows to {args.dynamic_out}",
              file=sys.stderr)
    if args.robustness_out:
        common.write_robustness(args.robustness_out)
        print(f"# wrote {len(common.RECORDED_ROBUSTNESS_ROWS)} "
              f"robustness rows to {args.robustness_out}",
              file=sys.stderr)
    if failed:
        print(f"# FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

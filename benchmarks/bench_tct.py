"""Fig. 10: total completion time of a Gavel-style trace (online arrivals).

Trace truncation is event-driven (``trace_scenario(open_ended=True)``):
jobs end when their JobDeparture fires on the simulator clock — a contended
job completes FEWER iterations in its window instead of holding its GPUs
longer, and never-admitted jobs depart from the pending queue (the K8s
deadline behavior).  The 'ideal' reference runs each job alone on a
dedicated cluster and ignores the event stream, so it keeps the legacy
iteration caps (the static bound) via a capped companion scenario.
"""
from __future__ import annotations

from repro.configs.metronome_testbed import MODEL_FLEET, trace_scenario
from repro.core.experiment import Policy
from repro.core.simulator import SimConfig
from repro.core.trace import cluster_load, generate_trace

from . import common
from .common import Timer, emit


def run() -> None:
    n_jobs = common.pick(10, 4)
    trace = generate_trace(MODEL_FLEET, duration_s=1800, total_gpus=13,
                           target_load=0.85, seed=1,
                           job_duration_range_s=(120, 240))[:n_jobs]
    load = cluster_load(trace, 13, 1800)
    cfg = SimConfig(duration_ms=common.pick(1_200_000, 120_000), seed=0,
                    jitter_std=0.01)
    open_scn = trace_scenario(trace, open_ended=True, name="gavel-trace")
    capped_scn = trace_scenario(trace, open_ended=False,
                                name="gavel-trace-capped")
    with Timer() as t:
        sw = common.run_sweep(
            [open_scn], [Policy(s) for s in ("metronome", "default",
                                             "diktyo")],
            cfg, origin="tct")
        sw_ideal = common.run_sweep([capped_scn], [Policy("ideal")], cfg,
                                    origin="tct")
    per_run_us = t.us / 4
    for sched, res in (("metronome", sw.get(open_scn.name, "metronome")),
                       ("default", sw.get(open_scn.name, "default")),
                       ("diktyo", sw.get(open_scn.name, "diktyo")),
                       ("ideal", sw_ideal.get(capped_scn.name, "ideal"))):
        emit(f"fig10_tct_{sched}", per_run_us,
             f"tct_s={res.sim.total_completion_ms/1e3:.1f};load={load:.2f};"
             f"n_jobs={len(trace)};queued_left={len(res.rejected)}")

"""Fig. 10: total completion time of a Gavel-style trace (online arrivals)."""
from __future__ import annotations

from repro.configs.metronome_testbed import MODEL_FLEET, make_snapshot
from repro.core.harness import run_trace_experiment
from repro.core.simulator import SimConfig
from repro.core.trace import cluster_load, generate_trace, trace_to_jobs
from repro.core.workload import Workload

from .common import Timer, emit


def run() -> None:
    trace = generate_trace(MODEL_FLEET, duration_s=1800, total_gpus=13,
                           target_load=0.85, seed=1,
                           job_duration_range_s=(120, 240))[:10]
    load = cluster_load(trace, 13, 1800)
    cfg = SimConfig(duration_ms=1_200_000, seed=0, jitter_std=0.01)
    for sched in ("metronome", "default", "diktyo", "ideal"):
        cluster, _, _ = make_snapshot("S1")
        jobs = trace_to_jobs(trace, MODEL_FLEET, time_scale=1.0)
        wls = [Workload(name=j.name, jobs=[j]) for j in jobs]
        for w in wls:
            for j in w.jobs:
                j.workload = w.name
                for t in j.tasks:
                    t.workload = w.name
        with Timer() as t:
            res = run_trace_experiment(sched, cluster, wls, cfg)
        emit(f"fig10_tct_{sched}", t.us,
             f"tct_s={res.sim.total_completion_ms/1e3:.1f};load={load:.2f};"
             f"n_jobs={len(jobs)};queued_left={len(res.rejected)}")

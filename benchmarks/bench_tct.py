"""Fig. 10: total completion time of a Gavel-style trace (online arrivals).

Trace truncation is event-driven (``trace_to_jobs(..., open_ended=True)`` +
``trace_departure_events``): jobs end when their JobDeparture fires on the
simulator clock — a contended job completes FEWER iterations in its window
instead of holding its GPUs longer, and never-admitted jobs depart from the
pending queue (the K8s deadline behavior)."""
from __future__ import annotations

from repro.configs.metronome_testbed import MODEL_FLEET, make_snapshot
from repro.core.harness import run_trace_experiment
from repro.core.simulator import SimConfig
from repro.core.trace import (cluster_load, generate_trace,
                              trace_departure_events, trace_to_jobs)
from repro.core.workload import Workload

from . import common
from .common import Timer, emit


def run() -> None:
    n_jobs = common.pick(10, 4)
    trace = generate_trace(MODEL_FLEET, duration_s=1800, total_gpus=13,
                           target_load=0.85, seed=1,
                           job_duration_range_s=(120, 240))[:n_jobs]
    load = cluster_load(trace, 13, 1800)
    cfg = SimConfig(duration_ms=common.pick(1_200_000, 120_000), seed=0,
                    jitter_std=0.01)
    for sched in ("metronome", "default", "diktyo", "ideal"):
        cluster, _, _ = make_snapshot("S1")
        # 'ideal' runs each job alone on a dedicated cluster and ignores the
        # event stream -> keep its legacy iteration caps (the static bound)
        open_ended = sched != "ideal"
        jobs = trace_to_jobs(trace, MODEL_FLEET, time_scale=1.0,
                             open_ended=open_ended)
        events = (trace_departure_events(trace, time_scale=1.0)
                  if open_ended else ())
        wls = [Workload(name=j.name, jobs=[j]) for j in jobs]
        for w in wls:
            for j in w.jobs:
                j.workload = w.name
                for t in j.tasks:
                    t.workload = w.name
        with Timer() as t:
            res = run_trace_experiment(sched, cluster, wls, cfg,
                                       events=events)
        emit(f"fig10_tct_{sched}", t.us,
             f"tct_s={res.sim.total_completion_ms/1e3:.1f};load={load:.2f};"
             f"n_jobs={len(jobs)};queued_left={len(res.rejected)}")

"""Tables VII/VIII + Fig. 13: ablations of the 3rd-stage optimization
(cushion slots) and the continuous monitoring mechanism."""
from __future__ import annotations

from repro.configs.metronome_testbed import SNAPSHOTS, snapshot_scenario
from repro.core.experiment import Policy

from . import common
from .common import Timer, emit

# paper's ablation: compact rotation (no cushion slots) and no
# Psi-maximizing offline recalculation — now one declarative Policy
ABLATIONS = (
    Policy("metronome", label="full"),
    Policy("metronome", skip_third_stage=True, rotation_mode="compact",
           label="wo_stage3"),
)


def _cfg(**kw):
    # more drift to make the cushions/monitor matter (paper runs real
    # hardware noise; we dial jitter up to the same effect)
    return common.bench_cfg(jitter_std=0.02, **kw)


def run() -> None:
    n_iter = common.pick(400, 30)
    for sid in common.pick(SNAPSHOTS, ("S2",)):
        scn = snapshot_scenario(sid, n_iterations=n_iter)
        with Timer() as t:
            sw = common.run_sweep([scn], ABLATIONS, _cfg(),
                                  origin="ablation")
            # the monitor lives in SimConfig, so the wo_monitor ablation is
            # the same policy under a monitor-less configuration
            sw_mon = common.run_sweep(
                [scn], [Policy("metronome", label="wo_monitor")],
                _cfg(monitor=False), origin="ablation")
        full = sw.get(sid, "full")
        variants = {"wo_stage3": sw.get(sid, "wo_stage3"),
                    "wo_monitor": sw_mon.get(sid, "wo_monitor")}
        hi, lo = full.high_priority, full.low_priority
        for label, v in variants.items():
            emit(f"tableVII_{sid}_{label}" if label == "wo_stage3"
                 else f"tableVIII_{sid}_{label}", t.us / 3,
                 f"lo_pct={100*(v.mean_s_per_1000(lo)/full.mean_s_per_1000(lo)-1):.2f};"
                 f"hi_pct={100*(v.mean_s_per_1000(hi)/full.mean_s_per_1000(hi)-1):.2f};"
                 f"gamma_delta_pp="
                 f"{100*(v.sim.avg_bw_utilization - full.sim.avg_bw_utilization):.2f};"
                 f"readj_full={full.sim.readjustments};"
                 f"readj_variant={v.sim.readjustments}")

"""Tables VII/VIII + Fig. 13: ablations of the 3rd-stage optimization
(cushion slots) and the continuous monitoring mechanism."""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import SNAPSHOTS, make_snapshot
from repro.core.harness import priority_split, run_experiment
from repro.core.simulator import SimConfig

from . import common
from .common import Timer, emit

def _cfg(**kw) -> SimConfig:
    # more drift to make the cushions/monitor matter (paper runs real
    # hardware noise; we dial jitter up to the same effect)
    return common.bench_cfg(jitter_std=0.02, **kw)


def run() -> None:
    n_iter = common.pick(400, 30)
    for sid in common.pick(SNAPSHOTS, ("S2",)):
        variants = {}
        for label, kw in (
            ("full", {}),
            # paper's ablation: compact rotation (no cushion slots) and no
            # Psi-maximizing offline recalculation
            ("wo_stage3", {"skip_third_stage": True,
                           "rotation_mode": "compact"}),
        ):
            cluster, wls, bg = make_snapshot(sid, n_iterations=n_iter)
            with Timer() as t:
                variants[label] = run_experiment(
                    "metronome", cluster, wls, _cfg(), background=bg,
                    **kw)
        cluster, wls, bg = make_snapshot(sid, n_iterations=n_iter)
        cfg = _cfg(monitor=False)
        variants["wo_monitor"] = run_experiment(
            "metronome", cluster, wls, cfg, background=bg)

        hi, lo = priority_split(wls)
        full = variants["full"]

        def agg(r, names):
            vals = [r.sim.time_per_1000_iters_s[j] for j in names
                    if j in r.sim.time_per_1000_iters_s]
            return float(np.mean(vals)) if vals else float("nan")

        for label in ("wo_stage3", "wo_monitor"):
            v = variants[label]
            emit(f"tableVII_{sid}_{label}" if label == "wo_stage3"
                 else f"tableVIII_{sid}_{label}", 0.0,
                 f"lo_pct={100*(agg(v, lo)/agg(full, lo)-1):.2f};"
                 f"hi_pct={100*(agg(v, hi)/agg(full, hi)-1):.2f};"
                 f"gamma_delta_pp="
                 f"{100*(v.sim.avg_bw_utilization - full.sim.avg_bw_utilization):.2f};"
                 f"readj_full={full.sim.readjustments};"
                 f"readj_variant={v.sim.readjustments}")

"""Roofline summary from the dry-run results (sections Dry-run / Roofline of
EXPERIMENTS.md are generated from the same data)."""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def run() -> None:
    if not os.path.exists(RESULTS):
        emit("roofline_missing", 0.0, "run=repro.launch.dryrun first")
        return
    with open(RESULTS) as f:
        results = json.load(f)
    for cell in sorted(results):
        info = results[cell]
        if info.get("status") != "ok":
            continue
        r = info["roofline"]
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        frac = r["compute_s"] / total if total else 0.0
        emit(f"roofline_{cell.replace('|', '_')}",
             info.get("compile_s", 0.0) * 1e6,
             f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
             f"collective_s={r['collective_s']:.4f};"
             f"bottleneck={info['bottleneck']};roofline_frac={frac:.4f};"
             f"model_vs_hlo={info.get('model_vs_hlo_flops')}")
